//! The parallel localized k-way FM algorithm (paper §7, Algorithm 7.1).
//!
//! Rounds: all boundary nodes enter a shared seed pool; threads poll
//! batches of seed nodes and run *localized* FM searches that expand to
//! neighbors of moved nodes. Searches own their nodes exclusively, move
//! them on a thread-local [`DeltaPartition`] first, and publish the
//! pending moves to the global partition as soon as the local gain is
//! positive. After the pool drains, the exact gains of the global move
//! sequence are recomputed in parallel (§6.3) and the sequence is
//! reverted to its best prefix.
//!
//! All mutable state (gain table, ownership bits, boundary buffer,
//! per-thread search scratch) lives in the refinement pipeline's
//! [`Workspace`] so uncoarsening reuses one allocation across levels;
//! [`fm_refine`] wraps a transient workspace for standalone callers.
//!
//! **Seeded (n-level) searches skip the global gain table.** Re-initializing
//! the table costs O(n·k) — fine once per uncoarsening level, but ruinous
//! when FM runs after every §9 batch uncontraction. With an explicit seed
//! set the searches are tiny, so PQ keys come from the delta-aware
//! on-the-fly gain instead (adjacent blocks only) and the whole invocation
//! stays O(Σ|I(touched)|), matching the dynamic-hypergraph batch cost.
//! Batch boundaries are in-place `DynamicHypergraph` uncontractions (not
//! materialized snapshots), so the partition the seeded search runs on is
//! the same pooled state every batch — which is why the sparse
//! ownership-reset and scratch invariants below matter.
//!
//! **Deterministic sibling:** under `ctx.deterministic` the pipeline runs
//! [`deterministic::fm_refine_deterministic_with_workspace`] instead — a
//! synchronous frozen-gain / prefix-selection variant (§11) that is
//! bit-identical for every thread count. This module's algorithm is the
//! asynchronous high-throughput path.

pub mod delta;
pub mod deterministic;
pub mod stop;

pub use delta::DeltaPartition;
pub use deterministic::fm_refine_deterministic;
pub use stop::AdaptiveStoppingRule;

use crate::coordinator::context::Context;
use crate::hypergraph::HypergraphOps;
use crate::partition::objective::{with_policy, GainPolicy};
use crate::partition::{
    gain_recalculation::{recalculate_gains_with_scratch_p, revert_to_best_prefix_p},
    GainTable, Move, PartitionState, PartitionedHypergraph,
};
use crate::refinement::pipeline::{SearchScratch, Workspace};
use crate::util::rng::hash2;
use crate::util::Rng;
use crate::{Gain, NodeId};
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Summary of an FM invocation.
#[derive(Clone, Copy, Debug, Default)]
pub struct FmStats {
    pub rounds: usize,
    pub improvement: Gain,
    pub moves_applied: usize,
}

/// Cap on net size during search expansion: gain updates on huge nets are
/// prohibitively expensive and rarely change decisions (the paper notes
/// FM outliers on instances with many large nets). Shared with the
/// deterministic variant's seeded candidate expansion.
pub(crate) const EXPANSION_NET_SIZE_LIMIT: usize = 512;

/// Parallel k-way FM refinement; returns round/improvement statistics.
///
/// Standalone entry point: allocates a transient [`Workspace`]. Inside
/// the uncoarsening loop use the pipeline instead, which carries the
/// workspace across levels.
pub fn fm_refine<H: HypergraphOps>(phg: &PartitionedHypergraph<H>, ctx: &Context) -> FmStats {
    fm_refine_with_seeds(phg, ctx, None)
}

/// FM restricted to the given seed nodes (the highly-localized variant
/// run after each n-level batch uncontraction, paper §9). `None` seeds
/// all boundary nodes.
pub fn fm_refine_with_seeds<H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
    seed_set: Option<&[NodeId]>,
) -> FmStats {
    let mut ws = Workspace::new(phg.k(), ctx.threads, phg.hypergraph().num_nodes());
    fm_refine_with_workspace(phg, ctx, seed_set, &mut ws)
}

/// The FM algorithm proper, running on a caller-provided [`Workspace`].
/// Global rounds (no seed set) re-initialize the workspace's gain table in
/// place for `phg`'s current assignment; seeded (n-level batch) rounds
/// skip the table entirely and run on on-the-fly gains, so their cost is
/// bounded by the searched region, not by n.
pub fn fm_refine_with_workspace<H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
    seed_set: Option<&[NodeId]>,
    ws: &mut Workspace<H::State>,
) -> FmStats {
    with_policy!(ctx.objective, P => fm_refine_with_workspace_p::<P, H>(phg, ctx, seed_set, ws))
}

fn fm_refine_with_workspace_p<P: GainPolicy, H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
    seed_set: Option<&[NodeId]>,
    ws: &mut Workspace<H::State>,
) -> FmStats {
    assert_eq!(phg.k(), ws.k(), "workspace was built for a different k");
    let n = phg.hypergraph().num_nodes();
    let threads = ctx.threads.max(1);
    ws.ensure_node_capacity(n);
    ws.ensure_threads(threads);
    // two-pin states never consult the §6.2 table: a node's exact best
    // move is one adjacency scan, so the table would be pure maintenance
    // overhead (and its memory is never allocated — see `Workspace::new`)
    let use_table = seed_set.is_none() && <H::State as PartitionState>::USE_GAIN_TABLE;
    if use_table {
        ws.prepare_gain_table_p::<P, H>(phg, threads);
    }
    let mut stats = FmStats::default();

    for round in 0..ctx.fm_max_rounds {
        // cancellation checkpoint: finish only whole rounds
        if ctx.cancel.is_expired() {
            ctx.cancel.note_early_stop();
            break;
        }
        // --- seed pool: boundary nodes (of the seed set), random order ---
        ws.boundary.clear();
        match seed_set {
            Some(set) => ws.boundary.extend(set.iter().copied().filter(|&u| phg.is_border(u))),
            None => ws.boundary.extend((0..n as NodeId).filter(|&u| phg.is_border(u))),
        }
        if ws.boundary.is_empty() {
            break;
        }
        Rng::new(hash2(ctx.seed ^ 0xf3, round as u64)).shuffle(&mut ws.boundary);
        if seed_set.is_none() {
            // Both modes maintain the all-clear ownership invariant across
            // rounds (per-search release of unmoved nodes + the sparse
            // end-of-round clear below), so this bulk clear is defensive
            // re-establishment only. Global rounds keep it because they
            // already pay the O(n·k) table init — O(n) is noise there and
            // shields external workspaces with unknown history; seeded
            // rounds must stay O(|region|) and rely on the invariant.
            ws.reset_owner(n);
        }

        let batch = ctx.fm_seeds_per_poll.max(1);
        let cursor = AtomicUsize::new(0);
        let global_moves: Mutex<Vec<Move>> = Mutex::new(Vec::new());
        let worker_panic = AtomicBool::new(false);
        {
            // field-disjoint borrows of the workspace: the scratch slots go
            // to the worker threads, the gain table / owner bits / seed
            // pool are shared read-side
            let gt = if use_table { Some(&ws.gain_table) } else { None };
            let owner = &ws.owner[..];
            let boundary = &ws.boundary[..];
            let cursor = &cursor;
            let global_moves = &global_moves;
            let worker_panic = &worker_panic;
            std::thread::scope(|s| {
                for sc in ws.scratch.iter_mut().take(threads) {
                    s.spawn(move || {
                        // panic isolation: searches publish whole move
                        // sequences, so containing an unwind here leaves
                        // the global move log valid; the flag routes the
                        // failure into the pipeline's poison/repair path
                        // instead of aborting the process
                        let caught = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                let mut search = LocalSearch::<P, H> {
                                    phg,
                                    gt,
                                    ctx,
                                    sc,
                                    _policy: PhantomData,
                                };
                                loop {
                                    let start = cursor.fetch_add(batch, Ordering::Relaxed);
                                    if start >= boundary.len() {
                                        break;
                                    }
                                    // cancellation checkpoint between seed
                                    // batches: published work stays applied
                                    if ctx.cancel.is_expired() {
                                        break;
                                    }
                                    crate::util::failpoints::fire(
                                        crate::util::failpoints::GAIN_TABLE_UPDATE,
                                        &ctx.cancel,
                                    );
                                    let end = (start + batch).min(boundary.len());
                                    search.run(&boundary[start..end], owner, global_moves);
                                }
                            }),
                        );
                        if caught.is_err() {
                            worker_panic.store(true, Ordering::Relaxed);
                        }
                    });
                }
            });
        }

        if worker_panic.load(Ordering::Relaxed) {
            // a worker died: its published moves are whole and consistent,
            // but the round's log may be incomplete — skip the §6.3 revert
            // bookkeeping and surface the failure so the pipeline poisons
            // this refiner and runs the validate/repair path
            ws.worker_panic = true;
            break;
        }

        // --- global recalculation + best-prefix revert (§6.3) ---
        let moves = global_moves.into_inner().unwrap_or_else(|e| e.into_inner());
        if moves.is_empty() {
            break;
        }
        let gains = recalculate_gains_with_scratch_p::<P, H>(phg, &moves, threads, &mut ws.recalc);
        let table = if use_table { Some(&ws.gain_table) } else { None };
        let (len, total) = revert_to_best_prefix_p::<P, H>(phg, &moves, &gains, table);
        // repair benefits of all touched nodes (paper: recompute after the
        // round instead of immediately after each move)
        if use_table {
            for m in &moves {
                ws.gain_table.recompute_benefit_p::<P, H>(phg, m.node);
            }
        }
        // restore the all-clear ownership invariant sparsely (globally
        // moved nodes kept their bit through the round)
        for m in &moves {
            ws.owner[m.node as usize].store(false, Ordering::Release);
        }
        stats.rounds = round + 1;
        stats.improvement += total;
        stats.moves_applied += len;
        if total <= 0 {
            break;
        }
    }
    stats
}

/// One thread's localized FM search bound to its reusable scratch.
/// `gt` is `None` for seeded (n-level batch) searches: PQ keys then come
/// from the delta-aware on-the-fly gain, keeping the search independent
/// of the global table (which is never initialized in that mode).
struct LocalSearch<'a, P: GainPolicy, H: HypergraphOps> {
    phg: &'a PartitionedHypergraph<H>,
    gt: Option<&'a GainTable>,
    ctx: &'a Context,
    sc: &'a mut SearchScratch,
    _policy: PhantomData<P>,
}

impl<'a, P: GainPolicy, H: HypergraphOps> LocalSearch<'a, P, H> {
    /// PQ key for `u`: the cached table gain when the table is live, the
    /// exact delta-aware gain otherwise (both are re-validated lazily at
    /// pop time, so transiently stale keys only cost a reinsertion).
    #[inline]
    fn key_for(&self, u: NodeId) -> Option<(crate::Gain, crate::BlockId)> {
        match self.gt {
            Some(gt) => gt.max_gain_move(self.phg, u),
            None => self.sc.delta.max_gain_move_p::<P, H>(self.phg, u),
        }
    }

    /// Algorithm 7.1's `LocalizedFMRefinement`.
    fn run(
        &mut self,
        seeds: &[NodeId],
        owner: &[AtomicBool],
        global_moves: &Mutex<Vec<Move>>,
    ) {
        self.sc.pq.clear();
        self.sc.delta.reset(self.phg.k());
        self.sc.acquired.clear();
        self.sc.moved_list.clear();
        self.sc.local_moves.clear();
        for &u in seeds {
            if try_acquire(owner, u) {
                self.sc.acquired.push(u);
                if let Some((g, _)) = self.key_for(u) {
                    self.sc.pq.insert(u, g);
                }
            }
        }
        let mut dtotal: Gain = 0;
        let n = self.phg.hypergraph().num_nodes();
        let mut stop = AdaptiveStoppingRule::new(self.ctx.fm_adaptive_alpha, n);

        while let Some((u, g)) = self.sc.pq.pop_max() {
            // lazy PQ: recompute the exact (delta-aware) best move
            let Some((g2, t2)) = self.sc.delta.max_gain_move_p::<P, H>(self.phg, u) else {
                continue;
            };
            if g2 < g {
                self.sc.pq.insert(u, g2);
                continue;
            }
            let from = self.sc.delta.block_of(self.phg, u);
            let Some(gain) = self.sc.delta.try_move_p::<P, H>(self.phg, u, t2) else { continue };
            debug_assert_eq!(gain, g2);
            dtotal += gain;
            self.sc.local_moves.push(Move { node: u, from, to: t2 });
            stop.push(gain);

            // improvement (or perfect-balance tie): publish to global
            if dtotal > 0 {
                if self.apply_globally(global_moves) {
                    dtotal = 0;
                    stop.improvement_found();
                } else {
                    break; // global balance conflict: abort this search
                }
            }

            // expand to neighbors of the moved node
            self.expand(u, owner);

            if stop.should_stop() {
                break;
            }
        }
        // drop unpublished local moves (ΔΠ discarded implicitly)
        self.sc.delta.clear();
        // release ownership of nodes that were not globally moved; the
        // moved-bitset lookup keeps this linear in |acquired| (the former
        // Vec::contains scan was quadratic in the move count)
        let sc = &mut *self.sc;
        for &u in &sc.acquired {
            if !sc.moved_bits.get(u as usize) {
                owner[u as usize].store(false, Ordering::Release);
            }
        }
        // reset the bitset sparsely for the next batch
        for &u in &sc.moved_list {
            sc.moved_bits.clear_bit(u as usize);
        }
    }

    /// Apply the pending local moves to the global partition (Alg. 7.1
    /// line 18). Returns false if a balance conflict forced a rollback.
    fn apply_globally(&mut self, global_moves: &Mutex<Vec<Move>>) -> bool {
        let sc = &mut *self.sc;
        let mut applied = 0usize;
        for m in sc.local_moves.iter() {
            if self.phg.try_move_p::<P>(m.node, m.to, self.gt).is_some() {
                applied += 1;
            } else {
                // rollback: another thread consumed the balance slack
                for a in sc.local_moves[..applied].iter().rev() {
                    self.phg.move_unchecked_p::<P>(a.node, a.from, self.gt);
                }
                // rolled-back nodes never reach the published move log, so
                // the post-round benefit repair would miss them — repair
                // here (update rules 2/4 leave movers' benefits stale)
                if let Some(gt) = self.gt {
                    for a in sc.local_moves[..applied].iter() {
                        gt.recompute_benefit_p::<P, H>(self.phg, a.node);
                    }
                }
                sc.local_moves.clear();
                sc.delta.clear();
                return false;
            }
        }
        for m in sc.local_moves.iter() {
            sc.moved_list.push(m.node);
            sc.moved_bits.set(m.node as usize);
        }
        global_moves.lock().unwrap().extend_from_slice(&sc.local_moves);
        sc.local_moves.clear();
        sc.delta.clear();
        true
    }

    /// Claim the neighbors of a moved node and (re)insert them in the PQ.
    ///
    /// PQ keys come from the *global gain table* (O(k) per node — the
    /// paper's "use the gain table … combining global gain table and ΔΠ
    /// data"); the exact delta-aware gain is recomputed lazily at pop
    /// time, so temporarily stale keys only cost a reinsertion.
    fn expand(&mut self, u: NodeId, owner: &[AtomicBool]) {
        let hg = self.phg.hypergraph();
        for &e in hg.incident_nets(u) {
            if hg.net_size(e) > EXPANSION_NET_SIZE_LIMIT {
                continue;
            }
            for &v in hg.pins(e) {
                if v == u {
                    continue;
                }
                if self.sc.pq.contains(v) {
                    if let Some((g, _)) = self.key_for(v) {
                        self.sc.pq.adjust(v, g);
                    }
                } else if !owner[v as usize].load(Ordering::Relaxed) && try_acquire(owner, v) {
                    self.sc.acquired.push(v);
                    if let Some((g, _)) = self.key_for(v) {
                        self.sc.pq.insert(v, g);
                    }
                }
            }
        }
    }
}

#[inline]
fn try_acquire(owner: &[AtomicBool], u: NodeId) -> bool {
    !owner[u as usize].swap(true, Ordering::AcqRel)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};
    use crate::hypergraph::Hypergraph;
    use crate::BlockId;
    use std::sync::Arc;

    fn ctx(k: usize, threads: usize, seed: u64) -> Context {
        Context::new(Preset::Default, k, 0.03).with_threads(threads).with_seed(seed)
    }

    fn perturbed(seed: u64, k: usize, flips: usize) -> PartitionedHypergraph {
        let p = PlantedParams { n: 300, m: 600, blocks: k, ..Default::default() };
        let hg = Arc::new(planted_hypergraph(&p, seed));
        let n = hg.num_nodes();
        let mut rng = Rng::new(seed ^ 0x123);
        let mut parts: Vec<BlockId> = (0..n).map(|u| (u * k / n) as BlockId).collect();
        for _ in 0..flips {
            parts[rng.next_below(n)] = rng.next_below(k) as BlockId;
        }
        let mut phg = PartitionedHypergraph::new(hg, k);
        phg.set_uniform_max_weight(0.3);
        phg.assign_all(&parts, 1);
        phg
    }

    #[test]
    fn fm_improves_and_accounts_exactly() {
        for threads in [1, 4] {
            let phg = perturbed(2, 2, 60);
            let before = phg.km1();
            let stats = fm_refine(&phg, &ctx(2, threads, 2));
            assert!(stats.improvement > 0, "t={threads}: no improvement");
            assert_eq!(phg.km1(), before - stats.improvement, "t={threads}");
            assert!(phg.is_balanced());
            phg.verify_consistency().unwrap();
        }
    }

    #[test]
    fn fm_beats_lp_on_non_trivial_instances() {
        // FM escapes local optima LP cannot (negative-gain move sets)
        let phg_lp = perturbed(7, 4, 90);
        let phg_fm = perturbed(7, 4, 90);
        assert_eq!(phg_lp.km1(), phg_fm.km1());
        crate::refinement::lp::lp_refine(&phg_lp, &ctx(4, 2, 7));
        fm_refine(&phg_fm, &ctx(4, 2, 7));
        crate::refinement::lp::lp_refine(&phg_fm, &ctx(4, 2, 7));
        assert!(
            phg_fm.km1() <= phg_lp.km1(),
            "FM({}) should be at least as good as LP({})",
            phg_fm.km1(),
            phg_lp.km1()
        );
    }

    #[test]
    fn fm_never_worsens() {
        for seed in 0..5u64 {
            let phg = perturbed(seed, 3, 40);
            let before = phg.km1();
            let stats = fm_refine(&phg, &ctx(3, 2, seed));
            assert!(stats.improvement >= 0, "best-prefix revert forbids regressions");
            assert!(phg.km1() <= before);
            phg.verify_consistency().unwrap();
        }
    }

    #[test]
    fn fm_respects_balance() {
        // the fixture allows ε = 0.3 (set_uniform_max_weight above) — FM
        // must stay within *those* limits; the ctx ε only shapes L_max
        // when the caller derives limits from it
        let phg = perturbed(11, 2, 50);
        fm_refine(&phg, &ctx(2, 4, 11));
        assert!(phg.is_balanced());
        assert!(phg.imbalance() <= 0.3 + 1e-9, "imbalance {}", phg.imbalance());
    }

    #[test]
    fn sequential_twoway_fm_for_bipartitions() {
        // the IP portfolio uses fm_refine with 1 thread on k=2
        let phg = perturbed(13, 2, 80);
        let before = phg.km1();
        let mut c = ctx(2, 1, 13);
        c.fm_max_rounds = 5;
        let stats = fm_refine(&phg, &c);
        assert!(stats.improvement > 0);
        assert_eq!(phg.km1(), before - stats.improvement);
    }

    #[test]
    fn workspace_reuse_matches_standalone() {
        // the same refinement through a reused workspace must behave like
        // the transient-workspace entry point (state fully re-prepared)
        let c = ctx(2, 1, 21);
        let phg_a = perturbed(21, 2, 60);
        let phg_b = perturbed(21, 2, 60);
        let sa = fm_refine(&phg_a, &c);
        let mut ws = Workspace::new(2, 1, phg_b.hypergraph().num_nodes());
        // dirty the workspace with an unrelated instance first
        let other = perturbed(22, 2, 30);
        fm_refine_with_workspace(&other, &c, None, &mut ws);
        let sb = fm_refine_with_workspace(&phg_b, &c, None, &mut ws);
        assert_eq!(sa.improvement, sb.improvement, "reuse must not change results");
        assert_eq!(phg_a.parts(), phg_b.parts());
    }

    #[test]
    fn rollback_on_balance_conflict_restores_partition_and_gain_table() {
        // Deterministic rollback: local search publishes a 2-move chain
        // whose second move violates balance. apply_globally must revert
        // the first move, leave the partition consistent and keep the
        // gain table exact (the sequential forward+backward update rules
        // cancel).
        let hg = Arc::new(Hypergraph::from_nets(
            6,
            &[vec![0, 1], vec![1, 2], vec![3, 4], vec![4, 5]],
            None,
            None,
        ));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        // block weights 3/3, slack of exactly 1 in block 1
        phg.set_max_weights(vec![4, 4]);
        phg.assign_all(&[0, 0, 0, 1, 1, 1], 1);
        let c = ctx(2, 1, 1);
        let mut ws = Workspace::new(2, 1, 6);
        ws.prepare_gain_table(&phg, 1);
        ws.ensure_threads(1);

        let parts_before = phg.parts();
        let sc = &mut ws.scratch[0];
        sc.local_moves.clear();
        sc.moved_list.clear();
        // both moves target block 1; the second exceeds L_max(1) = 4
        sc.local_moves.push(Move { node: 0, from: 0, to: 1 });
        sc.local_moves.push(Move { node: 1, from: 0, to: 1 });
        let global_moves: Mutex<Vec<Move>> = Mutex::new(Vec::new());
        let mut search = LocalSearch::<crate::partition::Km1Policy, _> {
            phg: &phg,
            gt: Some(&ws.gain_table),
            ctx: &c,
            sc,
            _policy: PhantomData,
        };
        assert!(!search.apply_globally(&global_moves), "conflict must be reported");

        assert!(global_moves.into_inner().unwrap().is_empty(), "nothing published");
        assert_eq!(phg.parts(), parts_before, "rollback must restore the assignment");
        phg.verify_consistency().unwrap();
        ws.gain_table
            .verify_against(&phg, &|_| false)
            .expect("gain table exact after rollback");
    }

    #[test]
    fn concurrent_rollbacks_keep_state_consistent() {
        // Stress the rollback path: many threads compete for a single
        // unit of balance slack, so apply_globally regularly loses the
        // optimistic reservation race mid-sequence. Afterwards the
        // partition must be consistent, balanced and exactly accounted,
        // and the gain-table penalties exact for every node (Lemma 6.1
        // holds across rollbacks because penalty updates are driven by
        // pin-count transitions under the net locks).
        for seed in 0..4u64 {
            let phg = perturbed(seed ^ 0x77, 2, 70);
            // shrink the slack to one unit above the heavier block
            let heavier = phg.block_weight(0).max(phg.block_weight(1));
            let mut tight = PartitionedHypergraph::new(phg.hypergraph_arc(), 2);
            tight.set_max_weights(vec![heavier + 1, heavier + 1]);
            tight.assign_all(&phg.parts(), 1);
            let before = tight.km1();
            let mut c = ctx(2, 4, seed);
            c.fm_max_rounds = 2;
            let mut ws = Workspace::new(2, 4, tight.hypergraph().num_nodes());
            let stats = fm_refine_with_workspace(&tight, &c, None, &mut ws);
            assert!(stats.improvement >= 0, "seed {seed}");
            assert_eq!(tight.km1(), before - stats.improvement, "seed {seed}");
            assert!(tight.is_balanced(), "seed {seed}");
            tight.verify_consistency().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            // penalties must be exact for all nodes after quiescence;
            // benefits of raced nodes are repaired per round for moved
            // nodes only, so restrict the benefit check accordingly
            ws.gain_table()
                .verify_against(&tight, &|_| true)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }
    }
}
