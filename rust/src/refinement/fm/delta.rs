//! Thread-local delta partition ΔΠ (paper §7).
//!
//! Localized FM searches perform moves *locally* first: the delta
//! partition overlays block assignments, pin counts and block weights on
//! top of the shared partition via hash tables, so other threads never see
//! speculative moves. Once a search finds an improvement, the pending
//! local moves are applied to the global partition and the overlay is
//! cleared. Memory stays proportional to the number of pending moves.
//!
//! The overlay does **not** borrow the partition it shadows: every method
//! takes the [`PartitionedHypergraph`] as an argument. That lets the
//! refinement pipeline keep one `DeltaPartition` per thread alive across
//! all uncoarsening levels (the hash tables keep their capacity) instead
//! of reallocating per FM call.

use crate::hypergraph::HypergraphOps;
use crate::metrics::Objective;
use crate::partition::objective::{GainPolicy, Km1Policy};
use crate::partition::PartitionedHypergraph;
use crate::util::fxhash::FxHashMap;
use crate::{BlockId, EdgeId, Gain, NodeId, NodeWeight};

#[derive(Default)]
pub struct DeltaPartition {
    k: usize,
    part: FxHashMap<NodeId, BlockId>,
    /// e → per-block deltas on Φ(e, ·), a short linear-scan list (one
    /// entry per block the local moves touched on that net) — keying by
    /// net instead of (e·k + b) keeps the overlay enumerable per net, so
    /// the combined-state gain scan visits only adjacent blocks instead
    /// of all k
    pin_delta: FxHashMap<EdgeId, Vec<(BlockId, i32)>>,
    weight_delta: Vec<NodeWeight>,
}

/// Find-or-insert a block's delta slot in a net's short delta list.
#[inline]
fn delta_slot(list: &mut Vec<(BlockId, i32)>, b: BlockId) -> &mut i32 {
    match list.iter().position(|&(db, _)| db == b) {
        Some(i) => &mut list[i].1,
        None => {
            list.push((b, 0));
            &mut list.last_mut().unwrap().1
        }
    }
}

impl DeltaPartition {
    pub fn new(k: usize) -> Self {
        DeltaPartition {
            k,
            part: FxHashMap::default(),
            pin_delta: FxHashMap::default(),
            weight_delta: vec![0; k],
        }
    }

    /// Re-target the overlay to a partition with `k` blocks, dropping all
    /// local state but keeping the allocated capacity.
    pub fn reset(&mut self, k: usize) {
        self.k = k;
        self.part.clear();
        self.pin_delta.clear();
        self.weight_delta.clear();
        self.weight_delta.resize(k, 0);
    }

    #[inline]
    pub fn block_of<H: HypergraphOps>(&self, phg: &PartitionedHypergraph<H>, u: NodeId) -> BlockId {
        self.part.get(&u).copied().unwrap_or_else(|| phg.block_of(u))
    }

    #[inline]
    pub fn pin_count<H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        e: EdgeId,
        b: BlockId,
    ) -> i64 {
        let base = phg.pin_count(e, b) as i64;
        let d = self
            .pin_delta
            .get(&e)
            .and_then(|list| list.iter().find(|&&(db, _)| db == b))
            .map(|&(_, d)| d)
            .unwrap_or(0);
        base + d as i64
    }

    #[inline]
    pub fn block_weight<H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        b: BlockId,
    ) -> NodeWeight {
        phg.block_weight(b) + self.weight_delta[b as usize]
    }

    /// Number of pending local moves.
    pub fn pending(&self) -> usize {
        self.part.len()
    }

    /// Local move with balance check against combined weights.
    /// Returns the exact local connectivity gain.
    pub fn try_move<H: HypergraphOps>(
        &mut self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
        to: BlockId,
    ) -> Option<Gain> {
        self.try_move_p::<Km1Policy, H>(phg, u, to)
    }

    /// [`Self::try_move`] for an arbitrary [`GainPolicy`]: the returned
    /// gain is the exact local objective delta in the combined state.
    /// Cut-net deltas come from the internal-net test on the combined pin
    /// counts (`Φ(e,to)=|e|` after ⇔ the net leaves the cut, `Φ(e,from)=|e|`
    /// before ⇔ it enters), which needs no connectivity tracking.
    pub fn try_move_p<P: GainPolicy, H: HypergraphOps>(
        &mut self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
        to: BlockId,
    ) -> Option<Gain> {
        debug_assert_eq!(self.k, phg.k(), "overlay not retargeted to this partition");
        let from = self.block_of(phg, u);
        if from == to {
            return None;
        }
        let w = phg.hypergraph().node_weight(u);
        if self.block_weight(phg, to) + w > phg.max_block_weight(to) {
            return None;
        }
        self.part.insert(u, to);
        self.weight_delta[from as usize] -= w;
        self.weight_delta[to as usize] += w;
        let mut gain: Gain = 0;
        for &e in phg.hypergraph().incident_nets(u) {
            let we = phg.hypergraph().net_weight(e);
            let list = self.pin_delta.entry(e).or_default();
            let dfrom = {
                let d = delta_slot(list, from);
                *d -= 1;
                *d
            };
            let dto = {
                let d = delta_slot(list, to);
                *d += 1;
                *d
            };
            let phi_from = phg.pin_count(e, from) as i64 + dfrom as i64;
            let phi_to = phg.pin_count(e, to) as i64 + dto as i64;
            debug_assert!(phi_from >= 0);
            match P::OBJECTIVE {
                Objective::Km1 => {
                    if phi_from == 0 {
                        gain += we;
                    }
                    if phi_to == 1 {
                        gain -= we;
                    }
                }
                Objective::Cut => {
                    let sz = phg.hypergraph().net_size(e) as i64;
                    if phi_to == sz {
                        gain += we;
                    }
                    if phi_from + 1 == sz {
                        gain -= we;
                    }
                }
                Objective::Soed => {
                    let sz = phg.hypergraph().net_size(e) as i64;
                    if phi_from == 0 {
                        gain += we;
                    }
                    if phi_to == 1 {
                        gain -= we;
                    }
                    if phi_to == sz {
                        gain += we;
                    }
                    if phi_from + 1 == sz {
                        gain -= we;
                    }
                }
            }
        }
        Some(gain)
    }

    /// Exact max-gain move in the combined (global + delta) state.
    ///
    /// Single pass over the incident nets (perf-critical; see
    /// EXPERIMENTS.md §Perf): with `W = Σ ω(e)` over `I(u)`, the penalty
    /// is `p(u,t) = W − Σ_{e: Φ(e,t)>0} ω(e)`, so accumulating the
    /// "present weight" per connected block in one sweep replaces the
    /// per-candidate re-scan.
    pub fn max_gain_move<H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
    ) -> Option<(Gain, BlockId)> {
        self.max_gain_move_p::<Km1Policy, H>(phg, u)
    }

    /// [`Self::max_gain_move`] for an arbitrary [`GainPolicy`]. The
    /// present-weight trick generalizes: `p(u,t) = pbase + corr(t)` where
    /// `pbase = Σ_e pc(ω, 0)` is target-independent and the correction
    /// `corr(t) = Σ_{e: Φ(e,t)>0} pc(ω, Φ(e,t)) − pc(ω, 0)` is only
    /// accumulated for connected blocks — for km1 this folds to exactly
    /// `W − present[t]`, so the km1 instantiation is the pre-refactor
    /// sweep bit-for-bit.
    pub fn max_gain_move_p<P: GainPolicy, H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
    ) -> Option<(Gain, BlockId)> {
        let from = self.block_of(phg, u);
        let w = phg.hypergraph().node_weight(u);
        let hg = phg.hypergraph();
        let mut benefit: Gain = 0;
        let mut pbase: Gain = 0;
        // corr[t] = Σ over nets with a pin in t of pc(ω,Φ(e,t)) − pc(ω,0)
        let mut corr: Vec<(BlockId, Gain)> = Vec::new();
        for &e in hg.incident_nets(u) {
            let we = hg.net_weight(e);
            let sz = if P::NEEDS_NET_SIZE { hg.net_size(e) as u32 } else { 0 };
            benefit += P::benefit_contrib(we, self.pin_count(phg, e, from) as u32, sz);
            let absent = P::penalty_contrib(we, 0, sz);
            pbase += absent;
            let mut add = |b: BlockId, phi: i64| {
                if b == from {
                    return;
                }
                let c = P::penalty_contrib(we, phi as u32, sz) - absent;
                match corr.iter_mut().find(|(pb, _)| *pb == b) {
                    Some((_, pw)) => *pw += c,
                    None => corr.push((b, c)),
                }
            };
            match self.pin_delta.get(&e) {
                None => {
                    for b in phg.connectivity_set(e) {
                        add(b, phg.pin_count(e, b) as i64);
                    }
                }
                Some(list) => {
                    // combined state, still adjacent-blocks-only: the
                    // global Λ(e) adjusted by local deltas …
                    for b in phg.connectivity_set(e) {
                        let d = list
                            .iter()
                            .find(|&&(db, _)| db == b)
                            .map(|&(_, d)| d)
                            .unwrap_or(0);
                        let phi = phg.pin_count(e, b) as i64 + d as i64;
                        if phi > 0 {
                            add(b, phi);
                        }
                    }
                    // … plus blocks the local moves alone made adjacent
                    for &(b, d) in list {
                        if d > 0 && phg.pin_count(e, b) == 0 {
                            add(b, d as i64);
                        }
                    }
                }
            }
        }
        let mut best: Option<(Gain, BlockId)> = None;
        for &(t, c) in &corr {
            if self.block_weight(phg, t) + w > phg.max_block_weight(t) {
                continue;
            }
            let g = benefit - (pbase + c);
            match best {
                None => best = Some((g, t)),
                Some((bg, bb)) => {
                    // total order (gain desc, weight asc, block id asc):
                    // candidate order follows Λ enumeration, which is not
                    // canonical on the sparse state — a first-encounter
                    // tie-break would be schedule-dependent there
                    let (wt, wb) = (self.block_weight(phg, t), self.block_weight(phg, bb));
                    if g > bg || (g == bg && (wt < wb || (wt == wb && t < bb))) {
                        best = Some((g, t));
                    }
                }
            }
        }
        best
    }

    /// Drop all local state (after the pending moves were applied
    /// globally, ΔΠ ← Π).
    pub fn clear(&mut self) {
        self.part.clear();
        self.pin_delta.clear();
        self.weight_delta.iter_mut().for_each(|w| *w = 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::Hypergraph;
    use std::sync::Arc;

    fn setup() -> PartitionedHypergraph {
        let hg = Arc::new(Hypergraph::from_nets(
            7,
            &[vec![0, 2], vec![0, 1, 3, 4], vec![3, 4, 6], vec![2, 5, 6]],
            None,
            None,
        ));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(1.0);
        phg.assign_all(&[0, 0, 0, 1, 1, 1, 1], 1);
        phg
    }

    #[test]
    fn overlay_isolates_global_state() {
        let phg = setup();
        let km1_before = phg.km1();
        let mut d = DeltaPartition::new(phg.k());
        let g = d.try_move(&phg, 0, 1).unwrap();
        assert_eq!(d.block_of(&phg, 0), 1);
        assert_eq!(phg.block_of(0), 0, "global untouched");
        assert_eq!(phg.km1(), km1_before);
        // local pin counts shifted
        assert_eq!(d.pin_count(&phg, 0, 0), 1);
        assert_eq!(d.pin_count(&phg, 0, 1), 1);
        assert_eq!(g, -1); // same as the global move test in partition::tests
        d.clear();
        assert_eq!(d.block_of(&phg, 0), 0);
        assert_eq!(d.pin_count(&phg, 0, 0), 2);
    }

    #[test]
    fn local_gains_match_global_replay() {
        let phg = setup();
        let mut d = DeltaPartition::new(phg.k());
        let mut rng = crate::util::Rng::new(9);
        let mut local_gains = Vec::new();
        let mut moves = Vec::new();
        let mut moved = vec![false; 7];
        for _ in 0..10 {
            let u = rng.next_below(7) as NodeId;
            if moved[u as usize] {
                continue;
            }
            let to = 1 - d.block_of(&phg, u);
            if let Some(g) = d.try_move(&phg, u, to) {
                moved[u as usize] = true;
                local_gains.push(g);
                moves.push((u, to));
            }
        }
        // replay on global: attributed gains must match one by one
        for ((u, to), lg) in moves.iter().zip(&local_gains) {
            let out = phg.move_unchecked(*u, *to, None);
            assert_eq!(out.attributed_gain, *lg);
        }
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn local_gains_match_global_replay_cut_and_soed() {
        use crate::partition::{CutNetPolicy, SoedPolicy};
        fn check<P: GainPolicy>() {
            let phg = setup();
            let mut d = DeltaPartition::new(phg.k());
            let mut rng = crate::util::Rng::new(17);
            let mut local_gains = Vec::new();
            let mut moves = Vec::new();
            let mut moved = vec![false; 7];
            for _ in 0..10 {
                let u = rng.next_below(7) as NodeId;
                if moved[u as usize] {
                    continue;
                }
                let to = 1 - d.block_of(&phg, u);
                if let Some(g) = d.try_move_p::<P, _>(&phg, u, to) {
                    moved[u as usize] = true;
                    local_gains.push(g);
                    moves.push((u, to));
                }
            }
            for ((u, to), lg) in moves.iter().zip(&local_gains) {
                let out = phg.move_unchecked_p::<P>(*u, *to, None);
                assert_eq!(out.attributed_gain, *lg);
            }
            phg.verify_consistency().unwrap();
        }
        check::<CutNetPolicy>();
        check::<SoedPolicy>();
    }

    #[test]
    fn max_gain_move_cut_matches_exhaustive() {
        use crate::partition::CutNetPolicy;
        let phg = setup();
        let d = DeltaPartition::new(phg.k());
        for u in 0..7 {
            let from = phg.block_of(u);
            let to = 1 - from;
            // exhaustive reference: gain_p from the global structure
            let want = phg.gain_p::<CutNetPolicy>(u, to);
            if let Some((g, t)) = d.max_gain_move_p::<CutNetPolicy, _>(&phg, u) {
                assert_eq!(t, to);
                assert_eq!(g, want, "node {u}");
            }
        }
    }

    #[test]
    fn balance_respected_locally() {
        let hg = Arc::new(Hypergraph::from_nets(4, &[vec![0, 1], vec![2, 3]], None, None));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_max_weights(vec![3, 3]);
        phg.assign_all(&[0, 0, 1, 1], 1);
        let mut d = DeltaPartition::new(2);
        assert!(d.try_move(&phg, 0, 1).is_some()); // block 1 now at 3 (locally)
        assert!(d.try_move(&phg, 1, 1).is_none(), "local weight limit enforced");
    }

    #[test]
    fn max_gain_move_sees_local_targets() {
        let phg = setup();
        let mut d = DeltaPartition::new(phg.k());
        let (g0, t0) = d.max_gain_move(&phg, 6).unwrap();
        let (g1, t1) = phg.max_gain_move(6).unwrap();
        assert_eq!((g0, t0), (g1, t1), "agrees with global when no deltas");
        d.try_move(&phg, 6, 0).unwrap();
        // now 6 is in block 0 locally; moving back should look good again
        let (_, back) = d.max_gain_move(&phg, 6).unwrap();
        assert_eq!(back, 1);
    }

    #[test]
    fn reset_retargets_k() {
        let phg = setup();
        let mut d = DeltaPartition::new(8);
        d.reset(phg.k());
        assert!(d.try_move(&phg, 0, 1).is_some());
        assert_eq!(d.pending(), 1);
        d.reset(phg.k());
        assert_eq!(d.pending(), 0);
        assert_eq!(d.block_of(&phg, 0), 0);
    }
}
