//! The uncoarsening-phase refinement algorithms (Algorithm 3.1, lines
//! 7–10): label propagation for the easy single-node moves, the parallel
//! localized FM algorithm for short non-trivial move sets, and flow-based
//! refinement for long, complex move sets with a global view — all
//! orchestrated by the [`pipeline::RefinementPipeline`], which owns the
//! long-lived workspace (gain table, FM ownership bits, boundary buffers,
//! per-thread search scratch) shared across uncoarsening levels.
//!
//! Every refiner has a **deterministic synchronous sibling** selected by
//! `ctx.deterministic` (paper §11): [`lp::lp_refine_deterministic`],
//! [`fm::fm_refine_deterministic`] and the single-worker flow schedule of
//! [`flow::flow_refine_with_workspace`]. The synchronous variants share
//! one [`DetScratch`] owned by the workspace — see the "Determinism
//! guarantees" section of `rust/ARCHITECTURE.md` for what exactly is
//! thread-count invariant and why.

pub mod flow;
pub mod fm;
pub mod lp;
pub mod pipeline;

pub use fm::{fm_refine, FmStats};
pub use lp::{lp_refine, lp_refine_deterministic};
pub use pipeline::{RefinementPipeline, Refiner, Workspace};
pub mod rebalance;
pub mod vcycle;

pub use rebalance::rebalance;
pub use vcycle::vcycle;

use crate::partition::Move;
use crate::{BlockId, Gain, NodeId, NodeWeight};

/// Shared scratch of the synchronous deterministic refiners (paper §11).
///
/// Both deterministic LP and deterministic FM follow the same sub-round
/// shape — collect candidate *moves against a frozen partition* into a
/// wishlist, totally order it, and apply balance-feasible prefixes per
/// block pair — so they share one set of buffers, owned by the refinement
/// [`Workspace`] and reused across rounds, refiner invocations and
/// uncoarsening levels (the generalization of the former LP-private
/// membership/wishlist vectors). Per-thread collection order is made
/// irrelevant by the total `(gain, node)` sort before any buffer is read,
/// which is what keeps the merged move buffers deterministic.
#[derive(Default)]
pub struct DetScratch {
    /// candidate nodes of the current round / sub-round
    pub(crate) members: Vec<NodeId>,
    /// desired moves `(gain, node, from, to)` against the frozen state;
    /// totally ordered before use
    pub(crate) desired: Vec<(Gain, NodeId, BlockId, BlockId)>,
    /// det-FM: persistent candidate set of a seeded invocation, expanded
    /// around applied moves between rounds
    pub(crate) candidates: Vec<NodeId>,
    /// det-FM: sequential move log of one round
    pub(crate) moves: Vec<Move>,
    /// det-FM: exact attributed gains of the move log (in order)
    pub(crate) gains: Vec<Gain>,
    /// det-FM: per-position balance admissibility of a prefix cut (the
    /// move's pair blocks are within their limits right after it) — the
    /// best-prefix revert may only cut at admissible positions
    pub(crate) admissible: Vec<bool>,
    /// per-pair node-weight prefixes handed to `lp::select_prefixes`
    pub(crate) w_st: Vec<NodeWeight>,
    pub(crate) w_ts: Vec<NodeWeight>,
}
