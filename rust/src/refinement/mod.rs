//! The uncoarsening-phase refinement algorithms (Algorithm 3.1, lines
//! 7–10): label propagation for the easy single-node moves, the parallel
//! localized FM algorithm for short non-trivial move sets, and flow-based
//! refinement for long, complex move sets with a global view — all
//! orchestrated by the [`pipeline::RefinementPipeline`], which owns the
//! long-lived workspace (gain table, FM ownership bits, boundary buffers,
//! per-thread search scratch) shared across uncoarsening levels.

pub mod flow;
pub mod fm;
pub mod lp;
pub mod pipeline;

pub use fm::{fm_refine, FmStats};
pub use lp::{lp_refine, lp_refine_deterministic};
pub use pipeline::{RefinementPipeline, Refiner, Workspace};
pub mod rebalance;
pub mod vcycle;

pub use rebalance::rebalance;
pub use vcycle::vcycle;
