//! Label propagation refinement (paper §6.1) and its deterministic
//! synchronous variant (paper §11).
//!
//! The parallel algorithm visits all nodes in rounds and greedily moves
//! each to its maximum-positive-gain block; a move whose *attributed*
//! gain turns out negative (a conflict with a concurrent move) is
//! immediately reverted. The deterministic variant computes all moves
//! against a frozen state and then performs balance-preserving prefix
//! swaps between block pairs, prioritized by gain.

use crate::coordinator::context::Context;
use crate::hypergraph::HypergraphOps;
use crate::parallel::parallel_chunks;
use crate::partition::objective::{with_policy, GainPolicy};
use crate::partition::PartitionedHypergraph;
use crate::util::rng::hash2;
use crate::util::Rng;
use crate::{BlockId, Gain, NodeId, NodeWeight};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Mutex;

/// Reusable label-propagation scratch: the per-round node visit order and
/// the localized frontier/next buffers. Owned by the refinement
/// `Workspace` so repeated LP invocations across uncoarsening levels stop
/// allocating per round; the capacity of the finest level is reused by
/// every coarser one. The deterministic variant's membership and
/// move-wishlist buffers live in the shared
/// [`DetScratch`](crate::refinement::DetScratch) instead (deterministic
/// FM uses the same sub-round shape, so the buffers are shared).
#[derive(Default)]
pub struct LpScratch {
    order: Vec<u32>,
    frontier: Vec<NodeId>,
    next: Vec<NodeId>,
}

/// Parallel label propagation; returns the total attributed improvement.
/// Convenience wrapper allocating throwaway scratch — pipeline callers go
/// through [`lp_refine_with_scratch`].
pub fn lp_refine<H: HypergraphOps>(phg: &PartitionedHypergraph<H>, ctx: &Context) -> Gain {
    lp_refine_with_scratch(phg, ctx, &mut LpScratch::default())
}

/// Parallel label propagation on reusable workspace scratch. Dispatches
/// on `ctx.objective` to the monomorphized policy instantiation.
pub fn lp_refine_with_scratch<H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
    scratch: &mut LpScratch,
) -> Gain {
    with_policy!(ctx.objective, P => lp_refine_with_scratch_p::<P, H>(phg, ctx, scratch))
}

fn lp_refine_with_scratch_p<P: GainPolicy, H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
    scratch: &mut LpScratch,
) -> Gain {
    let n = phg.hypergraph().num_nodes();
    let total = AtomicI64::new(0);
    for round in 0..ctx.lp_rounds {
        // cancellation checkpoint: finish only whole rounds
        if ctx.cancel.is_expired() {
            ctx.cancel.note_early_stop();
            break;
        }
        let order = &mut scratch.order;
        order.clear();
        order.extend(0..n as u32);
        Rng::new(hash2(ctx.seed, 0x19 ^ round as u64)).shuffle(order);
        let order = &*order;
        let moved_this_round = AtomicI64::new(0);
        parallel_chunks(n, ctx.threads, |_, s, e| {
            for &u in &order[s..e] {
                if !phg.is_border(u) {
                    continue;
                }
                if let Some((g, t)) = phg.max_gain_move_p::<P>(u) {
                    // only positive gain moves (paper: LP cannot escape
                    // local optima)
                    if g <= 0 {
                        continue;
                    }
                    let from = phg.block_of(u);
                    if let Some(out) = phg.try_move_p::<P>(u, t, None) {
                        if out.attributed_gain < 0 {
                            // conflict: revert immediately (§6.1)
                            let back = phg.move_unchecked_p::<P>(u, from, None);
                            moved_this_round.fetch_add(
                                out.attributed_gain + back.attributed_gain,
                                Ordering::Relaxed,
                            );
                        } else {
                            moved_this_round.fetch_add(out.attributed_gain, Ordering::Relaxed);
                        }
                    }
                }
            }
        });
        let delta = moved_this_round.load(Ordering::Relaxed);
        total.fetch_add(delta, Ordering::Relaxed);
        if delta <= 0 {
            break;
        }
    }
    total.load(Ordering::Relaxed)
}

/// Highly-localized label propagation (paper §9): restricted to the given
/// node set plus one-hop expansion — run after each batch uncontraction.
/// Convenience wrapper over [`lp_refine_localized_with_scratch`].
pub fn lp_refine_localized<H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
    nodes: &[NodeId],
) -> Gain {
    lp_refine_localized_with_scratch(phg, ctx, nodes, &mut LpScratch::default())
}

/// Localized label propagation whose frontier/next churn runs on reusable
/// workspace scratch (one n-level run performs thousands of batch
/// refinements; the buffers keep their capacity across all of them).
pub fn lp_refine_localized_with_scratch<H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
    nodes: &[NodeId],
    scratch: &mut LpScratch,
) -> Gain {
    with_policy!(ctx.objective, P => {
        lp_refine_localized_with_scratch_p::<P, H>(phg, ctx, nodes, scratch)
    })
}

fn lp_refine_localized_with_scratch_p<P: GainPolicy, H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
    nodes: &[NodeId],
    scratch: &mut LpScratch,
) -> Gain {
    let mut total: Gain = 0;
    scratch.frontier.clear();
    scratch.frontier.extend_from_slice(nodes);
    for _ in 0..ctx.lp_rounds.max(1) {
        // cancellation checkpoint: finish only whole frontier rounds
        if ctx.cancel.is_expired() {
            ctx.cancel.note_early_stop();
            break;
        }
        scratch.next.clear();
        let frontier = &scratch.frontier;
        let gained = AtomicI64::new(0);
        let next_mx = Mutex::new(&mut scratch.next);
        parallel_chunks(frontier.len(), ctx.threads, |_, s, e| {
            let mut local_next = Vec::new();
            for &u in &frontier[s..e] {
                if !phg.is_border(u) {
                    continue;
                }
                if let Some((g, t)) = phg.max_gain_move_p::<P>(u) {
                    if g > 0 {
                        let from = phg.block_of(u);
                        if let Some(out) = phg.try_move_p::<P>(u, t, None) {
                            if out.attributed_gain < 0 {
                                let back = phg.move_unchecked_p::<P>(u, from, None);
                                gained.fetch_add(
                                    out.attributed_gain + back.attributed_gain,
                                    Ordering::Relaxed,
                                );
                            } else {
                                gained.fetch_add(out.attributed_gain, Ordering::Relaxed);
                                // expand around the improving move
                                for &e in phg.hypergraph().incident_nets(u) {
                                    if phg.hypergraph().net_size(e) <= 64 {
                                        local_next
                                            .extend_from_slice(phg.hypergraph().pins(e));
                                    }
                                }
                            }
                        }
                    }
                }
            }
            next_mx.lock().unwrap().extend(local_next);
        });
        total += gained.load(Ordering::Relaxed);
        if scratch.next.is_empty() {
            break;
        }
        scratch.next.sort_unstable();
        scratch.next.dedup();
        std::mem::swap(&mut scratch.frontier, &mut scratch.next);
    }
    total
}

/// Does node `u` belong to sub-round `s` of deterministic-LP round
/// `round` (paper §11)? The salt is derived **here**, from `(seed,
/// round)` only — independent of `s` — so for a fixed round the
/// sub-rounds partition the node set: every node is considered in
/// exactly one sub-round. (The historic bug mixed `s` into the salt,
/// which put some nodes in several sub-rounds and others in none; the
/// membership test pins this function, the single decision point.)
#[inline]
fn det_in_sub_round(seed: u64, round: usize, s: u64, sub_rounds: u64, u: NodeId) -> bool {
    hash2(hash2(seed ^ 0x1b, round as u64), u as u64) % sub_rounds == s
}

/// Deterministic synchronous label propagation (paper §11): per sub-round,
/// compute the highest-gain move of each node against the frozen
/// partition, then select balance-preserving prefix swaps per block pair.
/// Convenience wrapper allocating throwaway scratch — pipeline callers go
/// through [`lp_refine_deterministic_with_scratch`].
pub fn lp_refine_deterministic<H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
) -> Gain {
    lp_refine_deterministic_with_scratch(phg, ctx, &mut crate::refinement::DetScratch::default())
}

/// Deterministic synchronous label propagation whose per-sub-round
/// membership and move-wishlist buffers live on the workspace's shared
/// [`DetScratch`](crate::refinement::DetScratch). Bit-identical to the
/// throwaway-scratch wrapper for any thread count (the wishlist is
/// totally ordered by (gain, node) before use).
pub fn lp_refine_deterministic_with_scratch<H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
    scratch: &mut crate::refinement::DetScratch,
) -> Gain {
    with_policy!(ctx.objective, P => {
        lp_refine_deterministic_with_scratch_p::<P, H>(phg, ctx, scratch)
    })
}

fn lp_refine_deterministic_with_scratch_p<P: GainPolicy, H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
    scratch: &mut crate::refinement::DetScratch,
) -> Gain {
    let n = phg.hypergraph().num_nodes();
    let k = phg.k();
    let sub_rounds = ctx.det_sub_rounds.max(1) as u64;
    let mut total: Gain = 0;
    for round in 0..ctx.lp_rounds {
        // cancellation checkpoint at the synchronous round boundary: a
        // partially executed round is never observable (§11 discipline —
        // when the deadline fires mid-run determinism is forfeited anyway,
        // but the partition is always left at a consistent round boundary)
        if ctx.cancel.is_expired() {
            ctx.cancel.note_early_stop();
            break;
        }
        let mut round_gain: Gain = 0;
        for s in 0..sub_rounds {
            // phase 1: calculate moves (frozen state); membership comes
            // from the partitioning predicate (see det_in_sub_round)
            scratch.members.clear();
            scratch.members.extend(
                (0..n as NodeId).filter(|&u| det_in_sub_round(ctx.seed, round, s, sub_rounds, u)),
            );
            let members = &scratch.members;
            scratch.desired.clear();
            {
                let desired = Mutex::new(&mut scratch.desired);
                parallel_chunks(members.len(), ctx.threads, |_, lo, hi| {
                    let mut local = Vec::new();
                    for &u in &members[lo..hi] {
                        if !phg.is_border(u) {
                            continue;
                        }
                        if let Some((g, t)) = phg.max_gain_move_p::<P>(u) {
                            if g > 0 {
                                local.push((g, u, phg.block_of(u), t));
                            }
                        }
                    }
                    desired.lock().unwrap().extend(local);
                });
            }
            let desired = &mut scratch.desired;
            // deterministic order: by gain desc, node id as tie-break
            desired.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));

            // phase 2: per block pair, select feasible prefixes and apply
            for sblk in 0..k as BlockId {
                for tblk in sblk + 1..k as BlockId {
                    let m_st: Vec<&(Gain, NodeId, BlockId, BlockId)> =
                        desired.iter().filter(|m| m.2 == sblk && m.3 == tblk).collect();
                    let m_ts: Vec<&(Gain, NodeId, BlockId, BlockId)> =
                        desired.iter().filter(|m| m.2 == tblk && m.3 == sblk).collect();
                    if m_st.is_empty() && m_ts.is_empty() {
                        continue;
                    }
                    let weight =
                        |m: &&(Gain, NodeId, BlockId, BlockId)| phg.hypergraph().node_weight(m.1);
                    let (i, j) = select_prefixes(
                        &m_st.iter().map(weight).collect::<Vec<_>>(),
                        &m_ts.iter().map(weight).collect::<Vec<_>>(),
                        phg.block_weight(sblk),
                        phg.block_weight(tblk),
                        phg.max_block_weight(sblk),
                        phg.max_block_weight(tblk),
                    );
                    for m in &m_st[..i] {
                        let out = phg.move_unchecked_p::<P>(m.1, tblk, None);
                        round_gain += out.attributed_gain;
                    }
                    for m in &m_ts[..j] {
                        let out = phg.move_unchecked_p::<P>(m.1, sblk, None);
                        round_gain += out.attributed_gain;
                    }
                }
            }
        }
        total += round_gain;
        if round_gain <= 0 {
            break;
        }
    }
    total
}

/// Two-pointer longest-feasible-prefix selection (paper §11): given the
/// node weights of the gain-sorted move sequences `s→t` and `t→s`, find
/// the longest prefixes whose application keeps both blocks within their
/// limits. Returns `(i, j)` prefix lengths.
pub fn select_prefixes(
    w_st: &[NodeWeight],
    w_ts: &[NodeWeight],
    weight_s: NodeWeight,
    weight_t: NodeWeight,
    max_s: NodeWeight,
    max_t: NodeWeight,
) -> (usize, usize) {
    // x(i,j) = weight moved s→t minus weight moved t→s
    let mut best: Option<(usize, usize)> = None;
    let (mut i, mut j) = (0usize, 0usize);
    let mut x: NodeWeight = 0;
    let feasible = |x: NodeWeight| weight_t + x <= max_t && weight_s - x <= max_s;
    loop {
        if feasible(x) && best.map_or(true, |(bi, bj)| i + j > bi + bj) {
            best = Some((i, j));
        }
        // advance the pointer of the side whose source receives more weight
        let advance_i = if i < w_st.len() && j < w_ts.len() {
            x <= 0
        } else if i < w_st.len() {
            true
        } else if j < w_ts.len() {
            false
        } else {
            break;
        };
        if advance_i {
            x += w_st[i];
            i += 1;
        } else {
            x -= w_ts[j];
            j += 1;
        }
    }
    best.unwrap_or((0, 0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};
    use std::sync::Arc;

    fn ctx(preset: Preset, k: usize, threads: usize, seed: u64) -> Context {
        Context::new(preset, k, 0.03).with_threads(threads).with_seed(seed)
    }

    fn perturbed_planted(seed: u64, k: usize) -> (PartitionedHypergraph, Vec<BlockId>) {
        let p = PlantedParams { n: 400, m: 700, blocks: k, ..Default::default() };
        let hg = Arc::new(planted_hypergraph(&p, seed));
        let n = hg.num_nodes();
        // planted blocks are contiguous ranges; perturb 15% of nodes
        let mut rng = Rng::new(seed ^ 77);
        let mut parts: Vec<BlockId> = (0..n).map(|u| (u * k / n) as BlockId).collect();
        for _ in 0..n / 7 {
            let u = rng.next_below(n);
            parts[u] = rng.next_below(k) as BlockId;
        }
        let mut phg = PartitionedHypergraph::new(hg, k);
        phg.set_uniform_max_weight(0.2);
        phg.assign_all(&parts, 2);
        (phg, parts)
    }

    #[test]
    fn lp_improves_perturbed_planted_partition() {
        let (phg, _) = perturbed_planted(1, 4);
        let before = phg.km1();
        let gain = lp_refine(&phg, &ctx(Preset::Default, 4, 2, 1));
        assert!(gain > 0, "expected improvement, got {gain}");
        assert_eq!(phg.km1(), before - gain, "attributed accounting exact");
        assert!(phg.is_balanced());
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn deterministic_lp_improves_and_is_reproducible() {
        let run = |threads: usize| {
            let (phg, _) = perturbed_planted(5, 2);
            let before = phg.km1();
            let g = lp_refine_deterministic(&phg, &ctx(Preset::Deterministic, 2, threads, 5));
            phg.verify_consistency().unwrap();
            assert!(phg.is_balanced());
            assert_eq!(phg.km1(), before - g);
            (g, phg.parts())
        };
        let (g1, p1) = run(1);
        let (g4, p4) = run(4);
        assert!(g1 > 0);
        assert_eq!(g1, g4, "same improvement for any thread count");
        assert_eq!(p1, p4, "bit-identical partitions");
    }

    #[test]
    fn deterministic_sub_rounds_partition_every_node() {
        // paper §11: per round, the sub-rounds partition the node set —
        // every node is a member of exactly one sub-round. This pins the
        // s-independence of the salt inside det_in_sub_round: mixing `s`
        // back into the hash (the historic bug) makes some nodes members
        // of several sub-rounds and others of none, failing this count.
        for seed in [0u64, 7, 0x1b2c3d] {
            for round in [0usize, 1, 4] {
                for sub_rounds in [1u64, 2, 5, 16] {
                    for u in 0..500u32 {
                        let hits = (0..sub_rounds)
                            .filter(|&s| det_in_sub_round(seed, round, s, sub_rounds, u))
                            .count();
                        assert_eq!(
                            hits, 1,
                            "node {u} in {hits} sub-rounds of {sub_rounds} (round {round})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn deterministic_lp_scratch_reuse_is_bit_identical() {
        // the workspace-scratch path must match the throwaway-scratch
        // wrapper exactly, including when the buffers are reused across
        // instances (the ROADMAP "Workspace-aware LP" leftover)
        let mut scratch = crate::refinement::DetScratch::default();
        for seed in [2u64, 9, 31] {
            let (phg_a, _) = perturbed_planted(seed, 3);
            let (phg_b, _) = perturbed_planted(seed, 3);
            let c = ctx(Preset::Deterministic, 3, 2, seed);
            let ga = lp_refine_deterministic(&phg_a, &c);
            let gb = lp_refine_deterministic_with_scratch(&phg_b, &c, &mut scratch);
            assert_eq!(ga, gb, "seed {seed}");
            assert_eq!(phg_a.parts(), phg_b.parts(), "seed {seed}");
        }
    }

    #[test]
    fn select_prefixes_respects_balance() {
        // block s at 10/10 (full), t at 6/10; moving 2 from s→t and 1 back
        let (i, j) = select_prefixes(&[2, 3], &[1], 10, 6, 10, 10);
        // all feasible: x after (2,1): t=6+2-1=7 ok, s=10-2+1=9 ok
        assert!(i >= 1 && j >= 1, "{i},{j}");
        // infeasible target: t already at limit, s→t impossible without swap
        let (i2, j2) = select_prefixes(&[5], &[], 10, 10, 10, 10);
        assert_eq!((i2, j2), (0, 0));
        // swap allows it
        let (i3, j3) = select_prefixes(&[5], &[5], 10, 10, 10, 10);
        assert_eq!((i3, j3), (1, 1));
    }

    #[test]
    fn lp_no_moves_on_optimal_partition() {
        // perfectly separated planted instance: LP must not degrade it
        let p = PlantedParams { n: 200, m: 300, blocks: 2, p_intra: 1.0, ..Default::default() };
        let hg = Arc::new(planted_hypergraph(&p, 9));
        let n = hg.num_nodes();
        let parts: Vec<BlockId> = (0..n).map(|u| (u * 2 / n) as BlockId).collect();
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(0.1);
        phg.assign_all(&parts, 1);
        assert_eq!(phg.km1(), 0);
        let g = lp_refine(&phg, &ctx(Preset::Default, 2, 2, 9));
        assert_eq!(g, 0);
        assert_eq!(phg.km1(), 0);
    }
}
