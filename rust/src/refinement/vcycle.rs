//! Iterated multilevel cycles (V-cycles, paper §4.3).
//!
//! "A popular approach to improve an existing k-way partition Π is the
//! iterated multilevel cycle technique: in the coarsening phase, the
//! algorithm forbids contractions between nodes that are not in the same
//! block in Π, thus preserving the already identified cut structure."
//! The paper uses community detection as a lighter-weight alternative
//! *during* partitioning; the V-cycle remains the classic post-processing
//! step and is provided here as the optional extension: the current
//! blocks act as "communities", the hierarchy is rebuilt, the existing
//! partition is projected down and refined at every level — initial
//! partitioning is skipped entirely.

use crate::coarsening;
use crate::coordinator::context::Context;
use crate::coordinator::partitioner::refine_level;
use crate::partition::PartitionedHypergraph;
use crate::refinement::RefinementPipeline;
use crate::BlockId;

/// Run `cycles` V-cycles on an existing partition; returns the improved
/// partition (never worse: each cycle keeps the better of before/after).
/// The refinement workspace is allocated once and reused across all
/// cycles and levels.
pub fn vcycle(phg: PartitionedHypergraph, ctx: &Context, cycles: usize) -> PartitionedHypergraph {
    let mut current = phg;
    let mut pipeline = RefinementPipeline::new(ctx, current.hypergraph().num_nodes());
    for _ in 0..cycles {
        let before = current.km1();
        let parts = current.parts();
        let hg = current.hypergraph_arc();
        // blocks as contraction communities: cut structure preserved
        let communities: Vec<u32> = parts.clone();
        let hierarchy = coarsening::coarsen(hg.clone(), ctx, Some(&communities));
        // project the *existing* partition onto the coarsest level
        let mut coarse_parts: Vec<BlockId> = parts.clone();
        for level in &hierarchy.levels {
            let mut next = vec![0 as BlockId; level.coarse.num_nodes()];
            for (u, &c) in level.fine_to_coarse.iter().enumerate() {
                next[c as usize] = coarse_parts[u];
            }
            coarse_parts = next;
        }
        // uncoarsen with the full refinement pipeline (no initial partitioning)
        let mut level_parts = coarse_parts;
        for i in (0..hierarchy.levels.len()).rev() {
            let refined =
                refine_level(hierarchy.levels[i].coarse.clone(), &level_parts, ctx, &mut pipeline);
            level_parts =
                coarsening::project_partition(&hierarchy.levels[i], &refined.parts());
        }
        let candidate = refine_level(hg, &level_parts, ctx, &mut pipeline);
        if candidate.km1() < before && candidate.is_balanced() {
            current = candidate;
        } else {
            break; // converged
        }
    }
    current
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::coordinator::partitioner;
    use crate::generators::{planted_hypergraph, PlantedParams};

    fn ctx() -> Context {
        let mut c = Context::new(Preset::Default, 4, 0.03).with_threads(2).with_seed(3);
        c.contraction_limit_factor = 24;
        c.ip_min_repetitions = 1;
        c.ip_max_repetitions = 2;
        c.fm_max_rounds = 2;
        c
    }

    #[test]
    fn vcycle_never_worsens() {
        let hg = planted_hypergraph(
            &PlantedParams { n: 500, m: 900, blocks: 4, p_intra: 0.85, ..Default::default() },
            7,
        );
        let ctx = ctx();
        let phg = partitioner::partition(&hg, &ctx);
        let before = phg.km1();
        let improved = vcycle(phg, &ctx, 2);
        assert!(improved.km1() <= before, "{} > {before}", improved.km1());
        assert!(improved.is_balanced());
        improved.verify_consistency().unwrap();
    }

    #[test]
    fn vcycle_preserves_cut_structure_constraint() {
        // a *perfect* partition must stay perfect through a V-cycle
        let hg = planted_hypergraph(
            &PlantedParams { n: 300, m: 500, blocks: 2, p_intra: 1.0, ..Default::default() },
            9,
        );
        let n = hg.num_nodes();
        let parts: Vec<BlockId> = (0..n).map(|u| (u * 2 / n) as BlockId).collect();
        let mut ctx = ctx();
        ctx.k = 2;
        let phg = crate::partition::PartitionedHypergraph::new(
            std::sync::Arc::new(hg),
            2,
        );
        phg.assign_all(&parts, 1);
        let phg = {
            let mut p = phg;
            p.set_uniform_max_weight(0.03);
            p
        };
        assert_eq!(phg.km1(), 0);
        let improved = vcycle(phg, &ctx, 1);
        assert_eq!(improved.km1(), 0, "V-cycle must not break an optimal cut");
    }
}
