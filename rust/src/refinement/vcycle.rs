//! Iterated multilevel cycles (V-cycles, paper §4.3).
//!
//! "A popular approach to improve an existing k-way partition Π is the
//! iterated multilevel cycle technique: in the coarsening phase, the
//! algorithm forbids contractions between nodes that are not in the same
//! block in Π, thus preserving the already identified cut structure."
//! The paper uses community detection as a lighter-weight alternative
//! *during* partitioning; the V-cycle remains the classic post-processing
//! step and is provided here as the optional extension: the current
//! blocks act as "communities", the hierarchy is rebuilt, the existing
//! partition is projected down and refined at every level — initial
//! partitioning is skipped entirely.

use crate::coarsening;
use crate::coordinator::context::Context;
use crate::partition::PartitionedHypergraph;
use crate::refinement::RefinementPipeline;
use crate::{BlockId, NodeWeight};

/// Run `cycles` V-cycles on an existing partition; returns the improved
/// partition (never worse: each cycle keeps the better of before/after).
/// The refinement workspace — gain table, FM scratch *and* the pooled
/// partition state — is allocated once and rebound across all cycles and
/// levels: the input partition's own buffers travel down to the coarsest
/// level and back up, so a whole V-cycle performs no structural
/// allocation of Π/Φ/Λ/lock storage.
pub fn vcycle(phg: PartitionedHypergraph, ctx: &Context, cycles: usize) -> PartitionedHypergraph {
    let hg = phg.hypergraph_arc();
    // standalone driver: arm the deadline for this run
    ctx.cancel.arm(ctx.time_limit);
    let mut pipeline = RefinementPipeline::new_for(ctx, &hg);
    let mut current = phg;
    // best assignment seen so far (values only; the memory stays pooled),
    // plus the caller's weight limits: if no cycle is ever accepted the
    // returned partition must carry the input's limits, not the uniform
    // ε-derived ones the rebinds install
    let mut best_parts = current.parts();
    let input_limits: Vec<NodeWeight> =
        (0..current.k() as BlockId).map(|b| current.max_block_weight(b)).collect();
    let mut accepted_any = false;
    let mut rejected_last = false;
    for _ in 0..cycles {
        // cancellation checkpoint: whole cycles only — `best_parts` always
        // holds the best accepted assignment, so stopping here returns it
        if ctx.cancel.is_expired() {
            ctx.cancel.note_early_stop();
            break;
        }
        let before = current.objective_value(ctx.objective);
        // at the loop top `best_parts` equals the current assignment
        // (initially by construction, afterwards by the acceptance
        // branch), so no second Π snapshot is needed per cycle.
        // blocks as contraction communities: cut structure preserved
        let hierarchy = coarsening::coarsen(hg.clone(), ctx, Some(&best_parts));
        // project the *existing* partition onto the coarsest level
        let mut coarse_parts: Vec<BlockId> = best_parts.clone();
        for level in &hierarchy.levels {
            let mut next = vec![0 as BlockId; level.coarse.num_nodes()];
            for (u, &c) in level.fine_to_coarse.iter().enumerate() {
                next[c as usize] = coarse_parts[u];
            }
            coarse_parts = next;
        }
        // uncoarsen with the full refinement pipeline (no initial
        // partitioning), rebinding the pooled state per level; the
        // coarsest level is `levels.len()` away from the finest, so
        // level-gated refiners (flows) concentrate on the finest levels
        current = pipeline.rebind_with_parts(current, hierarchy.coarsest(), &coarse_parts, ctx);
        pipeline.refine_at_distance(&current, ctx, hierarchy.levels.len());
        current = pipeline.uncoarsen(&hierarchy.levels, &hg, current, ctx);
        if current.objective_value(ctx.objective) < before && current.is_balanced() {
            best_parts = current.parts();
            accepted_any = true;
            rejected_last = false;
        } else {
            rejected_last = true;
            break; // converged
        }
    }
    if rejected_last {
        // restore the best accepted assignment in place by delta repair:
        // only nodes the rejected cycle actually moved are moved back, so
        // Φ/Λ/weights are touched only around the diff instead of being
        // rebuilt for the whole finest level
        current.apply_parts_delta(&best_parts, ctx.threads);
        if !accepted_any && input_limits.len() == current.k() {
            // every cycle rejected: hand back the input partition's own
            // block weight limits along with its assignment
            current.set_max_weights(input_limits);
        }
    }
    current
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::coordinator::partitioner;
    use crate::generators::{planted_hypergraph, PlantedParams};

    fn ctx() -> Context {
        let mut c = Context::new(Preset::Default, 4, 0.03).with_threads(2).with_seed(3);
        c.contraction_limit_factor = 24;
        c.ip_min_repetitions = 1;
        c.ip_max_repetitions = 2;
        c.fm_max_rounds = 2;
        c
    }

    #[test]
    fn vcycle_never_worsens() {
        let hg = planted_hypergraph(
            &PlantedParams { n: 500, m: 900, blocks: 4, p_intra: 0.85, ..Default::default() },
            7,
        );
        let ctx = ctx();
        let phg = partitioner::partition(&hg, &ctx);
        let before = phg.km1();
        let improved = vcycle(phg, &ctx, 2);
        assert!(improved.km1() <= before, "{} > {before}", improved.km1());
        assert!(improved.is_balanced());
        improved.verify_consistency().unwrap();
    }

    #[test]
    fn vcycle_preserves_cut_structure_constraint() {
        // a *perfect* partition must stay perfect through a V-cycle
        let hg = planted_hypergraph(
            &PlantedParams { n: 300, m: 500, blocks: 2, p_intra: 1.0, ..Default::default() },
            9,
        );
        let n = hg.num_nodes();
        let parts: Vec<BlockId> = (0..n).map(|u| (u * 2 / n) as BlockId).collect();
        let mut ctx = ctx();
        ctx.k = 2;
        let phg = crate::partition::PartitionedHypergraph::new(
            std::sync::Arc::new(hg),
            2,
        );
        phg.assign_all(&parts, 1);
        let phg = {
            let mut p = phg;
            p.set_uniform_max_weight(0.03);
            p
        };
        assert_eq!(phg.km1(), 0);
        let improved = vcycle(phg, &ctx, 1);
        assert_eq!(improved.km1(), 0, "V-cycle must not break an optimal cut");
    }
}
