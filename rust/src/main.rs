//! Mt-KaHyPar-rs command line interface.
//!
//! ```text
//! mtkahypar --hgr instance.hgr -k 8 [-e 0.03] [--preset default]
//!           [--threads 4] [--seed 0] [--time-limit SECS] [-o partition.out]
//! mtkahypar --graph instance.graph -k 8 ...            # Metis format
//! mtkahypar --demo                                      # synthetic demo
//! mtkahypar --hgr instance.hgr -k 8 --repartition changes.txt
//!                                  # warm-start repartitioning stream
//! ```
//!
//! `--repartition` partitions the instance once, then streams the change
//! batches from the file (see [`mtkahypar::repartition::parse_changes`]
//! for the line format) through the warm-start repartitioner, printing
//! one migration summary per batch and the final quality report.
//!
//! Exit codes: 0 success, 2 usage error, 3 input read/parse error,
//! 4 invalid configuration, 5 imbalanced result, 6 output write error.

use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::coordinator::report::{DegradationReport, PartitionReport};
use mtkahypar::generators::{self, PlantedParams};
use mtkahypar::graph::partitioner::partition_graph_arc;
use mtkahypar::io;
use mtkahypar::metrics::Objective;
use mtkahypar::partition::KStateChoice;
use mtkahypar::repartition::{self, RepartitionConfig, RepartitionSession};
use std::path::PathBuf;
use std::process::exit;
use std::sync::Arc;
use std::time::{Duration, Instant};

const EXIT_USAGE: i32 = 2;
const EXIT_READ: i32 = 3;
const EXIT_CONFIG: i32 = 4;
const EXIT_IMBALANCED: i32 = 5;
const EXIT_WRITE: i32 = 6;

struct Args {
    hgr: Option<PathBuf>,
    graph: Option<PathBuf>,
    demo: bool,
    k: usize,
    epsilon: f64,
    preset: Preset,
    objective: Objective,
    threads: usize,
    seed: u64,
    time_limit: Option<Duration>,
    kstate: KStateChoice,
    out: Option<PathBuf>,
    repartition: Option<PathBuf>,
    migration_cap: Option<f64>,
}

fn usage() -> ! {
    eprintln!(
        "usage: mtkahypar (--hgr FILE | --graph FILE | --demo) -k K [-e EPS] \
         [--preset speed|default|default-flows|quality|quality-flows|deterministic] \
         [--objective km1|cut|soed] [--threads T] [--seed S] [--time-limit SECS] \
         [--kstate dense|sparse|auto] [--repartition CHANGES] \
         [--migration-cap FRAC] [-o OUT]"
    );
    exit(EXIT_USAGE)
}

fn parse_args() -> Args {
    let mut args = Args {
        hgr: None,
        graph: None,
        demo: false,
        k: 2,
        epsilon: 0.03,
        preset: Preset::Default,
        objective: Objective::Km1,
        threads: 1,
        seed: 0,
        time_limit: None,
        kstate: KStateChoice::Auto,
        out: None,
        repartition: None,
        migration_cap: None,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let mut next = |name: &str| {
            it.next().unwrap_or_else(|| {
                eprintln!("missing value for {name}");
                usage()
            })
        };
        match a.as_str() {
            "--hgr" => args.hgr = Some(PathBuf::from(next("--hgr"))),
            "--graph" => args.graph = Some(PathBuf::from(next("--graph"))),
            "--demo" => args.demo = true,
            "-k" | "--blocks" => args.k = next("-k").parse().unwrap_or_else(|_| usage()),
            "-e" | "--epsilon" => args.epsilon = next("-e").parse().unwrap_or_else(|_| usage()),
            "--preset" => {
                args.preset = match next("--preset").as_str() {
                    "speed" => Preset::Speed,
                    "default" => Preset::Default,
                    "default-flows" => Preset::DefaultFlows,
                    "quality" => Preset::Quality,
                    "quality-flows" => Preset::QualityFlows,
                    "deterministic" => Preset::Deterministic,
                    other => {
                        eprintln!("unknown preset {other}");
                        usage()
                    }
                }
            }
            "--objective" => {
                args.objective = match next("--objective").as_str() {
                    "km1" => Objective::Km1,
                    "cut" => Objective::Cut,
                    "soed" => Objective::Soed,
                    other => {
                        eprintln!("unknown objective {other}");
                        usage()
                    }
                }
            }
            "--threads" | "-t" => {
                args.threads = next("--threads").parse().unwrap_or_else(|_| usage())
            }
            "--seed" | "-s" => args.seed = next("--seed").parse().unwrap_or_else(|_| usage()),
            "--time-limit" => {
                let secs: f64 = next("--time-limit").parse().unwrap_or_else(|_| usage());
                if !secs.is_finite() || secs <= 0.0 {
                    eprintln!("--time-limit must be a positive number of seconds");
                    usage()
                }
                args.time_limit = Some(Duration::from_secs_f64(secs));
            }
            "--kstate" => {
                args.kstate = match next("--kstate").as_str() {
                    "dense" => KStateChoice::Dense,
                    "sparse" => KStateChoice::Sparse,
                    "auto" => KStateChoice::Auto,
                    other => {
                        eprintln!("unknown kstate {other}");
                        usage()
                    }
                }
            }
            "--repartition" => {
                args.repartition = Some(PathBuf::from(next("--repartition")))
            }
            "--migration-cap" => {
                let frac: f64 = next("--migration-cap").parse().unwrap_or_else(|_| usage());
                if !frac.is_finite() || frac < 0.0 {
                    eprintln!("--migration-cap must be a non-negative fraction");
                    usage()
                }
                args.migration_cap = Some(frac);
            }
            "-o" | "--output" => args.out = Some(PathBuf::from(next("-o"))),
            "-h" | "--help" => usage(),
            other => {
                eprintln!("unknown argument {other}");
                usage()
            }
        }
    }
    if !args.demo && args.hgr.is_none() && args.graph.is_none() {
        usage()
    }
    if args.repartition.is_some() && args.graph.is_some() {
        eprintln!("--repartition runs on hypergraph instances (--hgr or --demo)");
        usage()
    }
    args
}

fn main() {
    let args = parse_args();
    let mut ctx = Context::new(args.preset, args.k, args.epsilon)
        .with_seed(args.seed)
        .with_threads(args.threads)
        .with_objective(args.objective)
        .with_kstate(args.kstate);
    ctx.time_limit = args.time_limit;
    if let Err(e) = ctx.validate() {
        eprintln!("invalid configuration: {e:#}");
        exit(EXIT_CONFIG);
    }

    if let Some(path) = &args.graph {
        let g = Arc::new(io::read_metis(path).unwrap_or_else(|e| {
            eprintln!("error reading {path:?}: {e:#}");
            exit(EXIT_READ)
        }));
        if let Err(e) = ctx.validate_for_instance(g.num_nodes()) {
            eprintln!("invalid configuration: {e:#}");
            exit(EXIT_CONFIG);
        }
        eprintln!("graph: n={} m={}", g.num_nodes(), g.num_edges() / 2);
        let start = Instant::now();
        let pg = partition_graph_arc(g, &ctx);
        let secs = start.elapsed().as_secs_f64();
        // same report as the hypergraph branch; on plain graphs km1 and
        // cut coincide (edge cut) and soed = 2 * cut, so --objective only
        // changes which of the equivalent values is highlighted
        let report = PartitionReport::from_partition(
            ctx.preset.name(),
            &pg,
            ctx.objective,
            secs,
            ctx.timer.snapshot(),
        );
        report.print();
        let degradation = DegradationReport::from_token(&ctx.cancel, ctx.time_limit);
        if degradation.degraded() {
            eprintln!("{}", degradation.summary());
        }
        if let Some(out) = &args.out {
            if let Err(e) = io::write_partition(&pg.parts(), out) {
                eprintln!("error writing {out:?}: {e:#}");
                exit(EXIT_WRITE);
            }
        }
        if !pg.is_balanced() {
            exit(EXIT_IMBALANCED);
        }
        return;
    }

    let hg = if args.demo {
        eprintln!("running on a synthetic planted instance (use --hgr for real inputs)");
        Arc::new(generators::planted_hypergraph(
            &PlantedParams { n: 5000, m: 9000, blocks: args.k.max(2), ..Default::default() },
            args.seed,
        ))
    } else {
        let path = args.hgr.as_ref().unwrap();
        Arc::new(io::read_hmetis(path).unwrap_or_else(|e| {
            eprintln!("error reading {path:?}: {e:#}");
            exit(EXIT_READ)
        }))
    };
    if let Err(e) = ctx.validate_for_instance(hg.num_nodes()) {
        eprintln!("invalid configuration: {e:#}");
        exit(EXIT_CONFIG);
    }
    eprintln!("hypergraph: n={} m={} pins={}", hg.num_nodes(), hg.num_nets(), hg.num_pins());

    if let Some(changes_path) = &args.repartition {
        let batches = repartition::parse_changes(changes_path).unwrap_or_else(|e| {
            eprintln!("error reading {changes_path:?}: {e:#}");
            exit(EXIT_READ)
        });
        let cfg = RepartitionConfig {
            max_migration_fraction: args.migration_cap,
            ..RepartitionConfig::default()
        };
        let start = Instant::now();
        let mut session = RepartitionSession::new(ctx.clone(), cfg);
        session.bind(hg);
        eprintln!("bound instance ({} change batches queued)", batches.len());
        for (i, batch) in batches.iter().enumerate() {
            match session.apply(batch) {
                Ok(ms) => eprintln!("batch {}: {}", i + 1, ms.summary()),
                Err(e) => {
                    eprintln!("batch {}: rejected change: {e}", i + 1);
                    exit(EXIT_READ);
                }
            }
        }
        let secs = start.elapsed().as_secs_f64();
        let rep = session.repartitioner().unwrap();
        let report = PartitionReport::from_partition(
            ctx.preset.name(),
            rep.partition(),
            ctx.objective,
            secs,
            ctx.timer.snapshot(),
        );
        report.print();
        let degradation = DegradationReport::from_token(&ctx.cancel, ctx.time_limit);
        if degradation.degraded() {
            eprintln!("{}", degradation.summary());
        }
        if let Some(out) = &args.out {
            if let Err(e) = io::write_partition(&rep.partition().parts(), out) {
                eprintln!("error writing {out:?}: {e:#}");
                exit(EXIT_WRITE);
            }
        }
        if !rep.partition().is_balanced() {
            exit(EXIT_IMBALANCED);
        }
        return;
    }

    let start = Instant::now();
    let phg = partitioner::partition_arc(hg, &ctx);
    let secs = start.elapsed().as_secs_f64();
    let report = PartitionReport::from_partition(
        ctx.preset.name(),
        &phg,
        ctx.objective,
        secs,
        ctx.timer.snapshot(),
    );
    report.print();
    let degradation = DegradationReport::from_token(&ctx.cancel, ctx.time_limit);
    if degradation.degraded() {
        eprintln!("{}", degradation.summary());
    }
    if let Some(out) = &args.out {
        if let Err(e) = io::write_partition(&phg.parts(), out) {
            eprintln!("error writing {out:?}: {e:#}");
            exit(EXIT_WRITE);
        }
    }
    if !phg.is_balanced() {
        exit(EXIT_IMBALANCED);
    }
}
