//! Shared-memory parallelism substrate.
//!
//! The paper builds on TBB; the offline registry here has no TBB/rayon, so
//! this module provides the primitives the framework needs on top of
//! `std::thread::scope`:
//!
//! * [`parallel_for`] — dynamically load-balanced index-range loops
//!   (atomic chunk counter, the pattern behind every "iterate over the
//!   nodes in parallel" step of the paper),
//! * [`parallel_chunks`] — static chunking with per-thread state,
//! * [`prefix_sum`] / [`parallel_prefix_sum`] — the contraction
//!   algorithm's adjacency-array construction primitive (paper §4.2),
//! * [`par_sort_by_key`] — parallel merge sort used for fingerprint grouping,
//! * [`TaskPool`] — a work-stealing task pool for the recursive
//!   bipartitioning calls of initial partitioning (paper §5).

pub mod pool;
pub mod scan;
pub mod sort;

pub use pool::TaskPool;
pub use scan::{parallel_prefix_sum, prefix_sum};
pub use sort::par_sort_by_key;

use std::sync::atomic::{AtomicUsize, Ordering};

/// Effective number of worker threads for a requested `t`
/// (clamped to at least 1).
#[inline]
pub fn effective_threads(t: usize) -> usize {
    t.max(1)
}

/// Dynamically scheduled parallel loop over `0..n`.
///
/// Threads repeatedly grab chunks of size `chunk` via an atomic counter and
/// call `f(i)` for each index. With `threads == 1` runs inline (no spawn),
/// which keeps single-threaded runs cheap and deterministic.
pub fn parallel_for<F>(n: usize, threads: usize, chunk: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = effective_threads(threads).min(n.max(1));
    if threads <= 1 || n <= chunk {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = counter.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Default chunk size heuristic: keep ~8 chunks per thread but at least 64
/// items per chunk to amortize the atomic.
#[inline]
pub fn auto_chunk(n: usize, threads: usize) -> usize {
    (n / (effective_threads(threads) * 8)).max(64)
}

/// Convenience: `parallel_for` with the automatic chunk size.
pub fn par_for_auto<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for(n, threads, auto_chunk(n, threads), f)
}

/// Statically partition `0..n` into `threads` contiguous ranges and run
/// `f(thread_id, start, end)` on each. Used where per-thread state matters
/// (e.g. thread-local rating maps) or where determinism requires a static
/// schedule (paper §11's "static load balancing").
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let threads = effective_threads(threads).min(n.max(1));
    if threads <= 1 {
        f(0, 0, n);
        return;
    }
    let per = (n + threads - 1) / threads;
    std::thread::scope(|s| {
        for t in 0..threads {
            let f = &f;
            s.spawn(move || {
                let start = t * per;
                let end = ((t + 1) * per).min(n);
                if start < end {
                    f(t, start, end);
                }
            });
        }
    });
}

/// Parallel map over `0..n` producing a `Vec<T>` (each index written once).
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = SharedSlice::new(&mut out);
        par_for_auto(n, threads, |i| {
            // SAFETY: each index written exactly once by one thread.
            unsafe { slots.write(i, f(i)) };
        });
    }
    out
}

/// A thin wrapper granting unsynchronized indexed writes into a slice from
/// multiple threads. Callers must guarantee disjoint index sets — the same
/// ownership argument the paper uses for its per-node arrays.
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _m: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}
unsafe impl<T: Send> Send for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        SharedSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _m: std::marker::PhantomData }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `val` to index `i`.
    ///
    /// # Safety
    /// No two threads may write the same index concurrently, and no one may
    /// read it while being written.
    #[inline]
    pub unsafe fn write(&self, i: usize, val: T) {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) = val };
    }

    /// Get a mutable reference to index `i` (same contract as `write`).
    ///
    /// # Safety
    /// See [`SharedSlice::write`].
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        unsafe { &mut *self.ptr.add(i) }
    }

    /// Read index `i`.
    ///
    /// # Safety
    /// The index must not be concurrently written.
    #[inline]
    pub unsafe fn read(&self, i: usize) -> &T {
        debug_assert!(i < self.len);
        unsafe { &*self.ptr.add(i) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all() {
        for threads in [1, 2, 4] {
            let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
            parallel_for(1000, threads, 16, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn chunks_cover_disjointly() {
        let hits: Vec<AtomicU64> = (0..103).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(103, 4, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_matches_serial() {
        let v = par_map(257, 4, |i| i * i);
        assert_eq!(v, (0..257).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_tiny() {
        parallel_for(0, 4, 8, |_| panic!("no items"));
        let v = par_map(1, 8, |i| i + 1);
        assert_eq!(v, vec![1]);
    }
}
