//! A work-stealing task pool for recursive bipartitioning (paper §5).
//!
//! The paper generates "tasks that can be dynamically load balanced using
//! work stealing" for the recursive calls after each bipartition. Tasks
//! here are closures that may spawn further tasks into the same pool.
//! Each worker owns a LIFO local stack (depth-first descent keeps the
//! working set small) and steals FIFO from victims when idle — the classic
//! Chase–Lev discipline realized with mutexed deques, which is plenty at
//! the task granularity of bipartitioning calls (milliseconds).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

type Task<'scope> = Box<dyn FnOnce(&TaskPool<'scope>) + Send + 'scope>;

/// Scoped work-stealing pool. Create with [`TaskPool::run`].
pub struct TaskPool<'scope> {
    queues: Vec<Mutex<VecDeque<Task<'scope>>>>,
    /// tasks submitted but not yet finished
    pending: AtomicUsize,
    idle: Mutex<()>,
    wake: Condvar,
    threads: usize,
}

impl<'scope> TaskPool<'scope> {
    /// Run `root` on a pool of `threads` workers; returns when the task
    /// graph is fully drained.
    pub fn run<F>(threads: usize, root: F)
    where
        F: FnOnce(&TaskPool<'scope>) + Send + 'scope,
    {
        let threads = threads.max(1);
        let pool = TaskPool {
            queues: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            pending: AtomicUsize::new(0),
            idle: Mutex::new(()),
            wake: Condvar::new(),
            threads,
        };
        pool.spawn(root);
        if threads == 1 {
            pool.worker(0);
            return;
        }
        std::thread::scope(|s| {
            let pool = &pool;
            for t in 0..threads {
                s.spawn(move || pool.worker(t));
            }
        });
    }

    /// Number of workers in the pool.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Submit a task (callable from inside running tasks).
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce(&TaskPool<'scope>) + Send + 'scope,
    {
        self.pending.fetch_add(1, Ordering::SeqCst);
        // push onto the shortest-queue heuristic: just use queue 0..t round robin
        let idx = self.pending.load(Ordering::Relaxed) % self.queues.len();
        self.queues[idx].lock().unwrap().push_back(Box::new(f));
        self.wake.notify_all();
    }

    fn pop_or_steal(&self, me: usize) -> Option<Task<'scope>> {
        if let Some(t) = self.queues[me].lock().unwrap().pop_back() {
            return Some(t);
        }
        for off in 1..self.queues.len() {
            let victim = (me + off) % self.queues.len();
            if let Some(t) = self.queues[victim].lock().unwrap().pop_front() {
                return Some(t);
            }
        }
        None
    }

    fn worker(&self, me: usize) {
        loop {
            if let Some(task) = self.pop_or_steal(me) {
                task(self);
                let left = self.pending.fetch_sub(1, Ordering::SeqCst) - 1;
                if left == 0 {
                    self.wake.notify_all();
                }
            } else {
                if self.pending.load(Ordering::SeqCst) == 0 {
                    return;
                }
                // brief blocking wait to avoid a hot spin while other
                // workers hold the remaining tasks
                let guard = self.idle.lock().unwrap();
                let _g = self
                    .wake
                    .wait_timeout(guard, std::time::Duration::from_micros(100))
                    .unwrap();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_recursive_task_tree() {
        for threads in [1, 2, 4] {
            let count = AtomicU64::new(0);
            let countr = &count;
            // binary recursion to depth 8 => 2^9 - 1 tasks
            fn rec<'s>(pool: &TaskPool<'s>, depth: usize, count: &'s AtomicU64) {
                count.fetch_add(1, Ordering::Relaxed);
                if depth > 0 {
                    pool.spawn(move |p| rec(p, depth - 1, count));
                    pool.spawn(move |p| rec(p, depth - 1, count));
                }
            }
            TaskPool::run(threads, move |p| rec(p, 8, countr));
            assert_eq!(count.load(Ordering::Relaxed), (1 << 9) - 1);
        }
    }

    #[test]
    fn uneven_tasks_complete() {
        let done = AtomicU64::new(0);
        let doner = &done;
        TaskPool::run(4, move |p| {
            for i in 0..64u64 {
                p.spawn(move |_| {
                    // simulate skewed work
                    let mut x = 0u64;
                    for j in 0..(i % 7) * 1000 {
                        x = x.wrapping_add(j);
                    }
                    std::hint::black_box(x);
                    doner.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(done.load(Ordering::Relaxed), 64);
    }
}
