//! Parallel sorting — used for fingerprint grouping in identical-net
//! detection (paper §4.2) and the deterministic group-by stages (§11).

use super::effective_threads;

/// Parallel stable sort by key: split into per-thread runs, sort each,
/// then k-way merge. Falls back to `sort_by_key` for small inputs.
pub fn par_sort_by_key<T, K, F>(xs: &mut [T], threads: usize, key: F)
where
    T: Send + Clone,
    K: Ord,
    F: Fn(&T) -> K + Sync,
{
    let n = xs.len();
    let threads = effective_threads(threads);
    if threads <= 1 || n < 1 << 13 {
        xs.sort_by_key(key);
        return;
    }
    let nruns = threads;
    let per = (n + nruns - 1) / nruns;
    // Sort disjoint runs in parallel.
    {
        let bounds: Vec<(usize, usize)> =
            (0..nruns).map(|t| (t * per, ((t + 1) * per).min(n))).filter(|(s, e)| s < e).collect();
        let ptr = SendPtr(xs.as_mut_ptr());
        std::thread::scope(|s| {
            for &(lo, hi) in &bounds {
                let key = &key;
                let ptr = ptr;
                s.spawn(move || {
                    let ptr = ptr; // capture the Send wrapper, not the raw field
                    // SAFETY: runs are disjoint.
                    let run = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(lo), hi - lo) };
                    run.sort_by_key(key);
                });
            }
        });
    }
    // Iterative pairwise merge of sorted runs.
    let mut runs: Vec<(usize, usize)> =
        (0..nruns).map(|t| (t * per, ((t + 1) * per).min(n))).filter(|(s, e)| s < e).collect();
    let mut buf: Vec<T> = xs.to_vec();
    while runs.len() > 1 {
        let mut next = Vec::with_capacity((runs.len() + 1) / 2);
        let mut i = 0;
        while i + 1 < runs.len() {
            let (a_lo, a_hi) = runs[i];
            let (b_lo, b_hi) = runs[i + 1];
            debug_assert_eq!(a_hi, b_lo);
            merge_into(&xs[a_lo..a_hi], &xs[b_lo..b_hi], &mut buf[a_lo..b_hi], &key);
            xs[a_lo..b_hi].clone_from_slice(&buf[a_lo..b_hi]);
            next.push((a_lo, b_hi));
            i += 2;
        }
        if i < runs.len() {
            next.push(runs[i]);
        }
        runs = next;
    }
}

fn merge_into<T: Clone, K: Ord>(a: &[T], b: &[T], out: &mut [T], key: &impl Fn(&T) -> K) {
    let (mut i, mut j, mut o) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        if key(&a[i]) <= key(&b[j]) {
            out[o] = a[i].clone();
            i += 1;
        } else {
            out[o] = b[j].clone();
            j += 1;
        }
        o += 1;
    }
    while i < a.len() {
        out[o] = a[i].clone();
        i += 1;
        o += 1;
    }
    while j < b.len() {
        out[o] = b[j].clone();
        j += 1;
        o += 1;
    }
}

struct SendPtr<T>(*mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matches_std_sort() {
        let mut rng = Rng::new(3);
        for &n in &[0usize, 1, 10, (1 << 13) + 7, 1 << 15] {
            let orig: Vec<u64> = (0..n).map(|_| rng.next_u64() % 1000).collect();
            let mut a = orig.clone();
            let mut b = orig;
            a.sort();
            par_sort_by_key(&mut b, 4, |x| *x);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn stability() {
        // sort pairs by first element only; second must keep insertion order
        let mut xs: Vec<(u32, u32)> = (0..20_000).map(|i| ((i * 7) % 13, i)).collect();
        par_sort_by_key(&mut xs, 4, |&(k, _)| k);
        for w in xs.windows(2) {
            assert!(w[0].0 <= w[1].0);
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "stability violated");
            }
        }
    }
}
