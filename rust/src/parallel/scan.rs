//! Prefix sums — the workhorse of the parallel contraction algorithm
//! (paper §4.2: "using parallel prefix sum operations to construct the
//! adjacency arrays of the contracted hypergraph").

use super::{effective_threads, parallel_chunks};

/// Sequential exclusive prefix sum over `xs`, returning the total.
/// `xs[i]` becomes the sum of the original `xs[0..i]`.
pub fn prefix_sum(xs: &mut [u64]) -> u64 {
    let mut acc = 0u64;
    for x in xs.iter_mut() {
        let v = *x;
        *x = acc;
        acc += v;
    }
    acc
}

/// Parallel exclusive prefix sum (two-pass block scan). Falls back to the
/// sequential version for small inputs or one thread.
pub fn parallel_prefix_sum(xs: &mut [u64], threads: usize) -> u64 {
    let n = xs.len();
    let threads = effective_threads(threads);
    if threads <= 1 || n < 1 << 14 {
        return prefix_sum(xs);
    }
    let nblocks = threads;
    let per = (n + nblocks - 1) / nblocks;
    let mut block_sums = vec![0u64; nblocks];
    {
        let sums = super::SharedSlice::new(&mut block_sums);
        let data = super::SharedSlice::new(xs);
        parallel_chunks(n, nblocks, |t, s, e| {
            let mut acc = 0u64;
            for i in s..e {
                // SAFETY: contiguous disjoint ranges per thread.
                unsafe {
                    let v = *data.read(i);
                    data.write(i, acc);
                    acc += v;
                }
            }
            unsafe { sums.write(t, acc) };
        });
        let _ = per;
    }
    let total = prefix_sum(&mut block_sums);
    {
        let data = super::SharedSlice::new(xs);
        let sums = &block_sums;
        parallel_chunks(n, nblocks, |t, s, e| {
            let off = sums[t];
            if off != 0 {
                for i in s..e {
                    unsafe { data.write(i, *data.read(i) + off) };
                }
            }
        });
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn sequential_basic() {
        let mut xs = vec![3, 1, 4, 1, 5];
        let total = prefix_sum(&mut xs);
        assert_eq!(xs, vec![0, 3, 4, 8, 9]);
        assert_eq!(total, 14);
    }

    #[test]
    fn parallel_matches_sequential() {
        let mut rng = Rng::new(1);
        for &n in &[0usize, 1, 100, 1 << 14, (1 << 16) + 13] {
            let orig: Vec<u64> = (0..n).map(|_| rng.next_below(100) as u64).collect();
            let mut a = orig.clone();
            let mut b = orig.clone();
            let ta = prefix_sum(&mut a);
            let tb = parallel_prefix_sum(&mut b, 4);
            assert_eq!(ta, tb);
            assert_eq!(a, b);
        }
    }
}
