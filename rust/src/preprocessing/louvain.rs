//! Parallel Louvain community detection (paper §4.3 and §11).
//!
//! Follows the parallel local-moving scheme of Staudt & Meyerhenke: nodes
//! are visited in parallel and moved to the neighboring community with the
//! best modularity gain; once local moving converges, the graph is
//! contracted by communities and the process recurses.
//!
//! The deterministic variant (paper §11) uses *synchronous* local moving
//! in sub-rounds: moves are calculated against a frozen state and applied
//! together. Community volumes here are integral (the bipartite edge-
//! weight model is pre-scaled to integers), so volume aggregation is
//! associative and the float-ordering pitfall the paper works around does
//! not arise — noted in DESIGN.md.

use crate::datastructures::RatingMap;
use crate::graph::{contraction as gcontract, Graph};
use crate::parallel::{par_for_auto, parallel_chunks, SharedSlice};
use crate::util::rng::hash2;
use crate::util::Rng;
use crate::NodeId;
use std::sync::atomic::{AtomicI64, AtomicU32, AtomicU64, Ordering};

#[derive(Clone, Debug)]
pub struct LouvainConfig {
    pub threads: usize,
    pub seed: u64,
    /// local-moving rounds per level
    pub max_rounds: usize,
    /// contraction levels
    pub max_levels: usize,
    /// stop a level when fewer than this fraction of nodes moved
    pub min_move_fraction: f64,
    /// synchronous (deterministic) local moving
    pub deterministic: bool,
    /// sub-rounds per synchronous round
    pub sub_rounds: usize,
}

impl Default for LouvainConfig {
    fn default() -> Self {
        LouvainConfig {
            threads: 1,
            seed: 0,
            max_rounds: 5,
            max_levels: 10,
            min_move_fraction: 0.01,
            deterministic: false,
            sub_rounds: 16,
        }
    }
}

/// Run multilevel Louvain; returns a community id per node.
pub fn louvain(g: &Graph, cfg: &LouvainConfig) -> Vec<u32> {
    let mut community: Vec<u32> = (0..g.num_nodes() as u32).collect();
    let mut level_graph = g.clone();
    // graph contraction drops intra-cluster edges; Louvain must keep their
    // volume, carried here as a per-coarse-node self-loop volume (2×
    // internal edge weight)
    let mut self_vol: Vec<i64> = vec![0; g.num_nodes()];
    for level in 0..cfg.max_levels {
        let moved = local_moving(&level_graph, &self_vol, cfg, level as u64);
        let clusters = moved.clusters;
        if moved.num_moves * 100 < level_graph.num_nodes() {
            // converged: fold this level's (near-identity) clustering in
            project(&mut community, &clusters);
            break;
        }
        project(&mut community, &clusters);
        // contract and recurse
        let rep = clusters_to_rep(&clusters);
        let contraction = gcontract::contract(&level_graph, &rep, cfg.threads);
        // accumulate self volume: old self loops + 2× intra-cluster weight
        let mut coarse_self = vec![0i64; contraction.coarse.num_nodes()];
        for u in level_graph.nodes() {
            let cu = contraction.fine_to_coarse[u as usize] as usize;
            coarse_self[cu] += self_vol[u as usize];
            for (v, w) in level_graph.neighbors(u) {
                if contraction.fine_to_coarse[v as usize] as usize == cu {
                    coarse_self[cu] += w; // counts both directions = 2×w
                }
            }
        }
        // rewrite community ids to coarse ids
        let mut remap = vec![0u32; level_graph.num_nodes()];
        par_for_auto(level_graph.num_nodes(), cfg.threads, {
            let remap = SharedSlice::new(&mut remap);
            let f2c = &contraction.fine_to_coarse;
            let rep = &rep;
            move |u| unsafe { remap.write(u, f2c[rep[u] as usize]) }
        });
        par_for_auto(community.len(), cfg.threads, {
            let community_s = SharedSlice::new(&mut community);
            let remap = &remap;
            move |u| unsafe {
                let c = *community_s.read(u);
                community_s.write(u, remap[c as usize]);
            }
        });
        if contraction.coarse.num_nodes() == level_graph.num_nodes() {
            break;
        }
        self_vol = coarse_self;
        level_graph = contraction.coarse;
    }
    // normalize ids to a consecutive range
    normalize(&mut community)
}

struct MoveResult {
    clusters: Vec<u32>,
    num_moves: usize,
}

/// One level of local moving. Cluster ids are node ids of this level.
fn local_moving(g: &Graph, self_vol: &[i64], cfg: &LouvainConfig, salt: u64) -> MoveResult {
    let n = g.num_nodes();
    let total_vol = (g.total_volume() + self_vol.iter().sum::<i64>()).max(1);
    let cluster: Vec<AtomicU32> = (0..n as u32).map(AtomicU32::new).collect();
    let volume: Vec<AtomicI64> = (0..n)
        .map(|u| AtomicI64::new(g.weighted_degree(u as NodeId) + self_vol[u]))
        .collect();
    let mut total_moves = 0usize;

    for round in 0..cfg.max_rounds {
        let moves_this_round = if cfg.deterministic {
            sync_round(g, self_vol, cfg, &cluster, &volume, total_vol, round as u64 ^ salt)
        } else {
            async_round(g, self_vol, cfg, &cluster, &volume, total_vol, round as u64 ^ salt)
        };
        total_moves += moves_this_round;
        if (moves_this_round as f64) < cfg.min_move_fraction * n as f64 {
            break;
        }
    }
    MoveResult {
        clusters: cluster.into_iter().map(|c| c.into_inner()).collect(),
        num_moves: total_moves,
    }
}

/// Modularity gain of moving `u` (volume `du`) into cluster with volume
/// `vol_c` and connection weight `w_uc`, out of its current cluster with
/// connection `w_cur` and remaining volume `vol_cur`:
/// ΔQ ∝ (w_uc − w_cur) − du·(vol_c − vol_cur)/total_vol.
#[inline]
fn gain(w_uc: i64, w_cur: i64, du: i64, vol_c: i64, vol_cur: i64, total_vol: i64) -> f64 {
    (w_uc - w_cur) as f64 - du as f64 * (vol_c - vol_cur) as f64 / total_vol as f64
}

#[allow(clippy::too_many_arguments)]
fn best_cluster(
    g: &Graph,
    self_vol: &[i64],
    u: NodeId,
    cur: u32,
    map: &mut RatingMap,
    cluster: &[AtomicU32],
    volume: &[AtomicI64],
    total_vol: i64,
) -> Option<u32> {
    map.clear();
    for (v, w) in g.neighbors(u) {
        if v != u {
            map.add(cluster[v as usize].load(Ordering::Relaxed) as u64, w as f64);
        }
    }
    let w_cur = map.get(cur as u64).unwrap_or(0.0) as i64;
    let du = g.weighted_degree(u) + self_vol[u as usize];
    let vol_cur = volume[cur as usize].load(Ordering::Relaxed) - du;
    let mut best: Option<(f64, u32)> = None;
    for (c, w_uc, _) in map.iter() {
        let c = c as u32;
        if c == cur {
            continue;
        }
        let vol_c = volume[c as usize].load(Ordering::Relaxed);
        let dq = gain(w_uc as i64, w_cur, du, vol_c, vol_cur, total_vol);
        if dq > 1e-9 {
            match best {
                None => best = Some((dq, c)),
                // deterministic tie-break on cluster id
                Some((bq, bc)) => {
                    if dq > bq + 1e-12 || ((dq - bq).abs() <= 1e-12 && c < bc) {
                        best = Some((dq, c));
                    }
                }
            }
        }
    }
    best.map(|(_, c)| c)
}

/// Asynchronous parallel local moving round (non-deterministic).
fn async_round(
    g: &Graph,
    self_vol: &[i64],
    cfg: &LouvainConfig,
    cluster: &[AtomicU32],
    volume: &[AtomicI64],
    total_vol: i64,
    salt: u64,
) -> usize {
    let n = g.num_nodes();
    // random visit order, derived deterministically but interleaved by
    // the scheduler (the async scheme)
    let mut order: Vec<u32> = (0..n as u32).collect();
    Rng::new(hash2(cfg.seed, salt)).shuffle(&mut order);
    let moves = AtomicU64::new(0);
    parallel_chunks(n, cfg.threads, |_, s, e| {
        let mut map = RatingMap::new(4096);
        for &u in &order[s..e] {
            let cur = cluster[u as usize].load(Ordering::Relaxed);
            if let Some(c) =
                best_cluster(g, self_vol, u, cur, &mut map, cluster, volume, total_vol)
            {
                let du = g.weighted_degree(u) + self_vol[u as usize];
                cluster[u as usize].store(c, Ordering::Relaxed);
                volume[cur as usize].fetch_sub(du, Ordering::Relaxed);
                volume[c as usize].fetch_add(du, Ordering::Relaxed);
                moves.fetch_add(1, Ordering::Relaxed);
            }
        }
    });
    moves.load(Ordering::Relaxed) as usize
}

/// Synchronous (deterministic) local moving round in sub-rounds.
fn sync_round(
    g: &Graph,
    self_vol: &[i64],
    cfg: &LouvainConfig,
    cluster: &[AtomicU32],
    volume: &[AtomicI64],
    total_vol: i64,
    salt: u64,
) -> usize {
    let n = g.num_nodes();
    let sub = cfg.sub_rounds.max(1) as u64;
    let mut total = 0usize;
    for s in 0..sub {
        // nodes of this sub-round (hash-assigned, thread-count independent)
        let members: Vec<u32> = (0..n as u32)
            .filter(|&u| hash2(cfg.seed ^ salt, u as u64) % sub == s)
            .collect();
        // phase 1: calculate moves against the frozen state
        let mut desired: Vec<(u32, u32)> = Vec::new(); // (node, target)
        {
            let desired_mx = std::sync::Mutex::new(&mut desired);
            parallel_chunks(members.len(), cfg.threads, |_, lo, hi| {
                let mut map = RatingMap::new(4096);
                let mut local = Vec::new();
                for &u in &members[lo..hi] {
                    let cur = cluster[u as usize].load(Ordering::Relaxed);
                    if let Some(c) =
                        best_cluster(g, self_vol, u, cur, &mut map, cluster, volume, total_vol)
                    {
                        local.push((u, c));
                    }
                }
                desired_mx.lock().unwrap().extend(local);
            });
        }
        // deterministic apply order (volumes integral => adds commute, the
        // sort guarantees identical iteration order for internal
        // determinism as well)
        desired.sort_unstable();
        for &(u, c) in &desired {
            let cur = cluster[u as usize].load(Ordering::Relaxed);
            if cur == c {
                continue;
            }
            let du = g.weighted_degree(u) + self_vol[u as usize];
            cluster[u as usize].store(c, Ordering::Relaxed);
            volume[cur as usize].fetch_sub(du, Ordering::Relaxed);
            volume[c as usize].fetch_add(du, Ordering::Relaxed);
        }
        total += desired.len();
    }
    total
}

/// Make cluster array idempotent: representative = smallest member id.
fn clusters_to_rep(clusters: &[u32]) -> Vec<NodeId> {
    let n = clusters.len();
    let mut min_member = vec![u32::MAX; n];
    for (u, &c) in clusters.iter().enumerate() {
        min_member[c as usize] = min_member[c as usize].min(u as u32);
    }
    clusters.iter().map(|&c| min_member[c as usize] as NodeId).collect()
}

/// community[u] (an id of the *previous* level) ← clusters[community[u]].
fn project(community: &mut [u32], clusters: &[u32]) {
    for c in community.iter_mut() {
        *c = clusters[*c as usize];
    }
}

/// Renumber community ids to 0..count, preserving first-appearance order.
fn normalize(community: &mut [u32]) -> Vec<u32> {
    let mut remap = crate::util::fxhash::FxHashMap::default();
    let mut next = 0u32;
    community
        .iter()
        .map(|&c| {
            *remap.entry(c).or_insert_with(|| {
                let id = next;
                next += 1;
                id
            })
        })
        .collect()
}

/// Modularity of a clustering (test/bench metric).
pub fn modularity(g: &Graph, community: &[u32]) -> f64 {
    let total = g.total_volume().max(1) as f64;
    let k = community.iter().copied().max().map_or(0, |c| c as usize + 1);
    let mut internal = vec![0i64; k];
    let mut vol = vec![0i64; k];
    for u in g.nodes() {
        let cu = community[u as usize] as usize;
        vol[cu] += g.weighted_degree(u);
        for (v, w) in g.neighbors(u) {
            if community[v as usize] as usize == cu {
                internal[cu] += w;
            }
        }
    }
    (0..k)
        .map(|c| internal[c] as f64 / total - (vol[c] as f64 / total).powi(2))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_cliques() -> Graph {
        let mut edges = Vec::new();
        for i in 0..8u32 {
            for j in i + 1..8 {
                edges.push((i, j, 1i64));
                edges.push((8 + i, 8 + j, 1));
            }
        }
        edges.push((0, 8, 1));
        Graph::from_edges(16, &edges, None)
    }

    #[test]
    fn finds_the_two_cliques() {
        let g = two_cliques();
        for det in [false, true] {
            let cfg = LouvainConfig { deterministic: det, threads: 2, ..Default::default() };
            let comms = louvain(&g, &cfg);
            // all of clique 1 together, all of clique 2 together, different
            assert!((1..8).all(|u| comms[u] == comms[1]), "det={det} {comms:?}");
            assert!((9..16).all(|u| comms[u] == comms[9]), "det={det}");
            assert_ne!(comms[1], comms[9], "det={det}");
        }
    }

    #[test]
    fn modularity_improves_over_singletons() {
        let g = two_cliques();
        let singletons: Vec<u32> = (0..16).collect();
        let comms = louvain(&g, &LouvainConfig::default());
        assert!(modularity(&g, &comms) > modularity(&g, &singletons) + 0.2);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let g = two_cliques();
        let run = |threads| {
            louvain(
                &g,
                &LouvainConfig {
                    deterministic: true,
                    threads,
                    seed: 42,
                    ..Default::default()
                },
            )
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a, b, "bit-equal across thread counts");
    }

    #[test]
    fn handles_trivial_graphs() {
        let g = Graph::from_edges(3, &[], None);
        let comms = louvain(&g, &LouvainConfig::default());
        assert_eq!(comms.len(), 3);
        let g1 = Graph::from_edges(1, &[], None);
        assert_eq!(louvain(&g1, &LouvainConfig::default()).len(), 1);
    }
}
