//! Preprocessing: community detection for community-aware coarsening
//! (paper §4.3) — transform the hypergraph into its bipartite graph
//! representation and run parallel Louvain modularity maximization.

pub mod louvain;

pub use louvain::{louvain, LouvainConfig};

use crate::hypergraph::{bipartite::bipartite_graph, Hypergraph};

/// Community id per hypergraph node, obtained by running Louvain on the
/// star expansion and dropping the net-vertices' assignments.
pub fn detect_communities(hg: &Hypergraph, cfg: &LouvainConfig) -> Vec<u32> {
    let g = bipartite_graph(hg);
    let comms = louvain(&g, cfg);
    comms[..hg.num_nodes()].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn communities_separate_planted_blocks() {
        // two densely intra-connected halves with a single bridging net
        let mut nets = Vec::new();
        for i in 0..10u32 {
            for j in i + 1..10 {
                nets.push(vec![i, j]);
                nets.push(vec![10 + i, 10 + j]);
            }
        }
        nets.push(vec![0, 10]); // bridge
        let hg = Hypergraph::from_nets(20, &nets, None, None);
        let cfg = LouvainConfig { threads: 2, ..LouvainConfig::default() };
        let comms = detect_communities(&hg, &cfg);
        assert_eq!(comms.len(), 20);
        // no community substantially spans both halves
        for c in comms.iter().copied().collect::<std::collections::HashSet<_>>() {
            let left = (0..10).filter(|&u| comms[u] == c).count();
            let right = (10..20).filter(|&u| comms[u] == c).count();
            assert!(
                left.min(right) <= 2,
                "community {c} spans halves: {left} | {right}"
            );
        }
    }
}
