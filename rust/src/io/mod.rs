//! Instance I/O: the hMetis hypergraph format and the Metis graph format
//! used by the paper's benchmark sets, plus partition-file output.

use crate::graph::Graph;
use crate::hypergraph::Hypergraph;
use crate::{BlockId, NodeId};
use crate::bail;
use crate::util::error::{Context, Error, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Read a hypergraph in hMetis format.
///
/// Header: `m n [fmt]` with fmt ∈ {“”, 1, 10, 11}: 1 = net weights,
/// 10 = node weights, 11 = both. Node ids in the file are 1-based.
pub fn read_hmetis(path: &Path) -> Result<Hypergraph> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut lines = BufReader::new(file)
        .lines()
        .map(|l| l.map_err(Error::from))
        .filter(|l| {
            l.as_ref()
                .map(|s| !s.trim_start().starts_with('%') && !s.trim().is_empty())
                .unwrap_or(true)
        });

    let header = lines.next().context("empty hMetis file")??;
    let head: Vec<usize> =
        header.split_whitespace().map(|t| t.parse()).collect::<Result<_, _>>()?;
    if head.len() < 2 {
        bail!("bad hMetis header: {header}");
    }
    let (m, n) = (head[0], head[1]);
    let fmt = head.get(2).copied().unwrap_or(0);
    if !matches!(fmt, 0 | 1 | 10 | 11) {
        bail!("bad hMetis fmt {fmt} (expected one of 0, 1, 10, 11): {header}");
    }
    if n == 0 {
        bail!("hMetis header declares zero nodes: {header}");
    }
    let has_net_w = fmt % 10 == 1;
    let has_node_w = fmt / 10 == 1;

    let mut nets = Vec::with_capacity(m);
    let mut net_w = Vec::with_capacity(m);
    for e in 0..m {
        let line = lines
            .next()
            .with_context(|| format!("truncated hMetis net section: {e} of {m} nets"))??;
        let mut toks = line.split_whitespace();
        let w = if has_net_w {
            let w = toks.next().context("missing net weight")?.parse::<i64>()?;
            if w <= 0 {
                bail!("net {} has non-positive weight {w}", e + 1);
            }
            w
        } else {
            1
        };
        // pin ids are 1-based in the file; 0 would wrap the u64 subtraction
        // and anything > n would index out of bounds downstream
        let pins: Vec<NodeId> = toks
            .map(|t| {
                let v = t.parse::<u64>()?;
                if v == 0 || v > n as u64 {
                    bail!("net {} has pin id {v} outside 1..={n}", e + 1);
                }
                Ok((v - 1) as NodeId)
            })
            .collect::<Result<_>>()?;
        if pins.is_empty() {
            bail!("net {} has no pins", e + 1);
        }
        net_w.push(w);
        nets.push(pins);
    }
    let node_w = if has_node_w {
        let mut w = Vec::with_capacity(n);
        for u in 0..n {
            let line = lines.next().with_context(|| {
                format!("truncated node-weight section: {u} of {n} weights")
            })??;
            let wt = line.trim().parse::<i64>()?;
            if wt <= 0 {
                bail!("node {} has non-positive weight {wt}", u + 1);
            }
            w.push(wt);
        }
        Some(w)
    } else {
        None
    };
    if lines.next().is_some() {
        bail!("trailing data after the declared {m} nets{}", if has_node_w {
            " and node weights"
        } else {
            ""
        });
    }
    Ok(Hypergraph::from_nets(n, &nets, node_w, Some(net_w)))
}

/// Write a hypergraph in hMetis format (with weights iff non-unit).
pub fn write_hmetis(hg: &Hypergraph, path: &Path) -> Result<()> {
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    let unit_nets = hg.nets().all(|e| hg.net_weight(e) == 1);
    let unit_nodes = hg.nodes().all(|u| hg.node_weight(u) == 1);
    let fmt = match (unit_nodes, unit_nets) {
        (true, true) => String::new(),
        (true, false) => " 1".into(),
        (false, true) => " 10".into(),
        (false, false) => " 11".into(),
    };
    writeln!(out, "{} {}{}", hg.num_nets(), hg.num_nodes(), fmt)?;
    for e in hg.nets() {
        let mut line = String::new();
        if !unit_nets {
            line.push_str(&format!("{} ", hg.net_weight(e)));
        }
        let pins: Vec<String> = hg.pins(e).iter().map(|&p| (p + 1).to_string()).collect();
        line.push_str(&pins.join(" "));
        writeln!(out, "{line}")?;
    }
    if !unit_nodes {
        for u in hg.nodes() {
            writeln!(out, "{}", hg.node_weight(u))?;
        }
    }
    Ok(())
}

/// Read a graph in Metis format. Header: `n m [fmt [ncon]]`, fmt ∈
/// {“”, 1 (edge weights), 10 (node weights), 11}. 1-based ids.
pub fn read_metis(path: &Path) -> Result<Graph> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut lines = BufReader::new(file)
        .lines()
        .map(|l| l.map_err(Error::from))
        .filter(|l| {
            l.as_ref()
                .map(|s| !s.trim_start().starts_with('%') && !s.trim().is_empty())
                .unwrap_or(true)
        });

    let header = lines.next().context("empty Metis file")??;
    let head: Vec<usize> =
        header.split_whitespace().map(|t| t.parse()).collect::<Result<_, _>>()?;
    if head.len() < 2 {
        bail!("bad Metis header: {header}");
    }
    let n = head[0];
    let fmt = head.get(2).copied().unwrap_or(0);
    if !matches!(fmt, 0 | 1 | 10 | 11) {
        bail!("bad Metis fmt {fmt} (expected one of 0, 1, 10, 11): {header}");
    }
    let has_edge_w = fmt % 10 == 1;
    let has_node_w = (fmt / 10) % 10 == 1;

    let mut adj: Vec<Vec<(NodeId, i64)>> = vec![Vec::new(); n];
    let mut node_w = vec![1i64; n];
    for u in 0..n {
        let line = lines
            .next()
            .with_context(|| format!("truncated Metis adjacency: {u} of {n} lines"))??;
        let mut toks = line.split_whitespace();
        if has_node_w {
            let wt: i64 = toks.next().context("missing node weight")?.parse()?;
            if wt <= 0 {
                bail!("node {} has non-positive weight {wt}", u + 1);
            }
            node_w[u] = wt;
        }
        loop {
            let Some(v_tok) = toks.next() else { break };
            let v: u64 = v_tok.parse()?;
            // neighbor ids are 1-based; 0 would wrap the subtraction
            if v == 0 || v > n as u64 {
                bail!("node {} has neighbor id {v} outside 1..={n}", u + 1);
            }
            let w = if has_edge_w {
                let w = toks.next().context("missing edge weight")?.parse::<i64>()?;
                if w <= 0 {
                    bail!("edge ({}, {v}) has non-positive weight {w}", u + 1);
                }
                w
            } else {
                1
            };
            adj[u].push(((v - 1) as NodeId, w));
        }
    }
    Ok(Graph::from_adjacency(&adj, Some(node_w)))
}

/// Write a partition as one block id per line (KaHyPar convention).
pub fn write_partition(blocks: &[BlockId], path: &Path) -> Result<()> {
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    for &b in blocks {
        writeln!(out, "{b}")?;
    }
    Ok(())
}

/// Read a partition file.
pub fn read_partition(path: &Path) -> Result<Vec<BlockId>> {
    let file = std::fs::File::open(path)?;
    BufReader::new(file)
        .lines()
        .map(|l| Ok(l?.trim().parse::<BlockId>()?))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hmetis_roundtrip_unit() {
        let hg = Hypergraph::from_nets(5, &[vec![0, 1, 2], vec![2, 3], vec![3, 4]], None, None);
        let dir = std::env::temp_dir().join("mtkahypar_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("unit.hgr");
        write_hmetis(&hg, &p).unwrap();
        let rd = read_hmetis(&p).unwrap();
        assert_eq!(rd.num_nodes(), 5);
        assert_eq!(rd.num_nets(), 3);
        assert_eq!(rd.pins(0), &[0, 1, 2]);
        rd.validate().unwrap();
    }

    #[test]
    fn hmetis_roundtrip_weighted() {
        let hg = Hypergraph::from_nets(
            3,
            &[vec![0, 1], vec![1, 2]],
            Some(vec![4, 5, 6]),
            Some(vec![7, 8]),
        );
        let dir = std::env::temp_dir().join("mtkahypar_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("weighted.hgr");
        write_hmetis(&hg, &p).unwrap();
        let rd = read_hmetis(&p).unwrap();
        assert_eq!(rd.node_weight(2), 6);
        assert_eq!(rd.net_weight(1), 8);
        rd.validate().unwrap();
    }

    #[test]
    fn metis_parse() {
        let dir = std::env::temp_dir().join("mtkahypar_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.graph");
        std::fs::write(&p, "% comment\n3 2\n2\n1 3\n2\n").unwrap();
        let g = read_metis(&p).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 4);
        g.validate().unwrap();
    }

    #[test]
    fn partition_roundtrip() {
        let dir = std::env::temp_dir().join("mtkahypar_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("part.txt");
        write_partition(&[0, 1, 1, 0, 2], &p).unwrap();
        assert_eq!(read_partition(&p).unwrap(), vec![0, 1, 1, 0, 2]);
    }
}

/// Read a MatrixMarket coordinate file as a hypergraph (row-net model:
/// rows become nets over their nonzero columns — the paper's SPM
/// benchmark construction, §12).
pub fn read_matrix_market(path: &Path) -> Result<Hypergraph> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut lines = BufReader::new(file).lines();
    let header = loop {
        let line = lines.next().context("empty MatrixMarket file")??;
        if !line.starts_with('%') {
            break line;
        }
    };
    let dims: Vec<usize> =
        header.split_whitespace().map(|t| t.parse()).collect::<Result<_, _>>()?;
    if dims.len() < 3 {
        bail!("bad MatrixMarket size line: {header}");
    }
    let (rows, cols, nnz) = (dims[0], dims[1], dims[2]);
    let mut nets: Vec<Vec<NodeId>> = vec![Vec::new(); rows];
    let mut seen = 0usize;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = it.next().context("row")?.parse()?;
        let c: usize = it.next().context("col")?.parse()?;
        if r == 0 || c == 0 || r > rows || c > cols {
            bail!("entry ({r},{c}) out of bounds");
        }
        let pin = (c - 1) as NodeId;
        if !nets[r - 1].contains(&pin) {
            nets[r - 1].push(pin);
        }
        seen += 1;
    }
    if seen < nnz {
        bail!("truncated MatrixMarket file: {seen}/{nnz} entries");
    }
    let nets: Vec<Vec<NodeId>> = nets.into_iter().filter(|n| n.len() >= 2).collect();
    Ok(Hypergraph::from_nets(cols, &nets, None, None))
}

#[cfg(test)]
mod mm_tests {
    use super::*;

    #[test]
    fn matrix_market_row_net_model() {
        let dir = std::env::temp_dir().join("mtkahypar_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n% comment\n3 4 6\n1 1 1.0\n1 2 2.0\n2 2 0.5\n2 3 1.5\n3 3 1.0\n3 4 2.5\n",
        )
        .unwrap();
        let hg = read_matrix_market(&p).unwrap();
        assert_eq!(hg.num_nodes(), 4); // columns
        assert_eq!(hg.num_nets(), 3); // rows with ≥ 2 nonzeros
        assert_eq!(hg.pins(0), &[0, 1]);
        hg.validate().unwrap();
    }

    #[test]
    fn matrix_market_rejects_truncation() {
        let dir = std::env::temp_dir().join("mtkahypar_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.mtx");
        std::fs::write(&p, "%%MatrixMarket\n2 2 3\n1 1 1\n").unwrap();
        assert!(read_matrix_market(&p).is_err());
    }
}
