//! Figs. 16 & 18 + Table 3 (hypergraph part) — Mt-KaHyPar vs the solver
//! classes: sequential quality (PaToH-like at both presets), parallel
//! fast (Zoltan-like), deterministic (BiPart-like). Reports median
//! improvements, speed factors and the Wilcoxon signed-rank test.

use mtkahypar::benchkit::{self, baselines, suites};
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::util::stats;
use std::sync::Arc;
use std::time::Instant;

struct Measured {
    quality: Vec<f64>,
    time: Vec<f64>,
}

fn measure(
    name: &str,
    instances: &[suites::HgInstance],
    k: usize,
    f: impl Fn(&Arc<mtkahypar::hypergraph::Hypergraph>, &Context) -> mtkahypar::partition::PartitionedHypergraph,
) -> Measured {
    let mut quality = Vec::new();
    let mut time = Vec::new();
    for inst in instances {
        let mut ctx = Context::new(Preset::Default, k, 0.03).with_threads(4).with_seed(11);
        ctx.contraction_limit_factor = 24;
        ctx.ip_min_repetitions = 2;
        ctx.ip_max_repetitions = 4;
        ctx.fm_max_rounds = 4;
        let start = Instant::now();
        let phg = f(&inst.hg, &ctx);
        time.push(start.elapsed().as_secs_f64());
        quality.push(phg.km1() as f64 + 1.0);
        let _ = name;
    }
    Measured { quality, time }
}

fn compare(base: (&str, &Measured), other: (&str, &Measured)) -> Vec<String> {
    let improvements: Vec<f64> = base
        .1
        .quality
        .iter()
        .zip(&other.1.quality)
        .map(|(b, o)| (o / b - 1.0) * 100.0)
        .collect();
    let speed = stats::geometric_mean(&other.1.time) / stats::geometric_mean(&base.1.time);
    let (z, p) = stats::wilcoxon_signed_rank(&base.1.quality, &other.1.quality);
    vec![
        base.0.to_string(),
        other.0.to_string(),
        format!("{:.1}%", stats::median(&improvements)),
        format!("{speed:.2}x"),
        format!("{z:.2}"),
        format!("{p:.4}"),
    ]
}

fn main() {
    for (suite_name, instances, k) in [
        ("M_HG (Fig. 16)", suites::suite_mhg(), 8),
        ("L_HG (Fig. 18)", suites::suite_lhg(), 8),
    ] {
        let d = measure("Mt-KaHyPar-D", &instances, k, |hg, ctx| {
            partitioner::partition_arc(hg.clone(), ctx)
        });
        let qf = measure("Mt-KaHyPar-Q-F", &instances, k, |hg, ctx| {
            let mut c = Context::new(Preset::QualityFlows, ctx.k, ctx.epsilon)
                .with_threads(ctx.threads)
                .with_seed(ctx.seed);
            c.contraction_limit_factor = ctx.contraction_limit_factor;
            c.ip_min_repetitions = 2;
            c.ip_max_repetitions = 4;
            c.fm_max_rounds = 4;
            partitioner::partition_arc(hg.clone(), &c)
        });
        let sdet = measure("Mt-KaHyPar-SDet", &instances, k, |hg, ctx| {
            let mut c = Context::new(Preset::Deterministic, ctx.k, ctx.epsilon)
                .with_threads(ctx.threads)
                .with_seed(ctx.seed);
            c.contraction_limit_factor = ctx.contraction_limit_factor;
            partitioner::partition_arc(hg.clone(), &c)
        });
        let patoh = measure("PaToH-like", &instances, k, baselines::patoh_like);
        let zoltan = measure("Zoltan-like", &instances, k, baselines::zoltan_like);
        let bipart = measure("BiPart-like", &instances, k, baselines::bipart_like);

        let rows = vec![
            compare(("Mt-KaHyPar-D", &d), ("PaToH-like", &patoh)),
            compare(("Mt-KaHyPar-D", &d), ("Zoltan-like", &zoltan)),
            compare(("Mt-KaHyPar-SDet", &sdet), ("BiPart-like", &bipart)),
            compare(("Mt-KaHyPar-SDet", &sdet), ("Zoltan-like", &zoltan)),
            compare(("Mt-KaHyPar-Q-F", &qf), ("PaToH-like", &patoh)),
            compare(("Mt-KaHyPar-Q-F", &qf), ("Mt-KaHyPar-D", &d)),
        ];
        benchkit::print_table(
            &format!("Figs. 16/18 + Table 3 — comparison on {suite_name}"),
            &["base", "compared", "median improv. of base", "rel. slowdown of other", "Z", "p"],
            &rows,
        );
    }
    println!(
        "\n=> paper expectations: D beats Zoltan-class by ~23% median (L_HG) and PaToH-D by \
         ~6.6%; SDet beats BiPart by ~200%; Q-F ≈ best sequential quality."
    );
}
