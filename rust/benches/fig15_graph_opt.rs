//! Fig. 15 — effect of the plain-graph data-structure optimizations
//! (paper §10): the same multilevel algorithm on the graph-specialized
//! structures vs on the generic hypergraph structures (each edge a 2-pin
//! net), per component and overall, plus a quality check.

use mtkahypar::benchkit::{self, suites};
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::graph::partitioner::partition_graph_arc;
use mtkahypar::metrics;
use mtkahypar::util::stats;
use std::sync::Arc;
use std::time::Instant;

const PHASES: [&str; 4] = ["coarsening", "initial_partitioning", "label_propagation", "fm"];

fn main() {
    let instances = suites::suite_lg();
    let mut graph_total = Vec::new();
    let mut hyper_total = Vec::new();
    let mut phase_speedups: Vec<Vec<f64>> = vec![Vec::new(); PHASES.len()];
    let mut quality_rows = Vec::new();

    for inst in &instances {
        // graph-optimized pipeline
        let mut gctx = Context::new(Preset::Default, 8, 0.03).with_threads(4).with_seed(2);
        gctx.contraction_limit_factor = 24;
        gctx.ip_min_repetitions = 2;
        gctx.ip_max_repetitions = 4;
        gctx.fm_max_rounds = 3;
        let t0 = Instant::now();
        let pg = partition_graph_arc(inst.g.clone(), &gctx);
        let graph_secs = t0.elapsed().as_secs_f64();
        graph_total.push(graph_secs);

        // generic hypergraph pipeline on the 2-pin-net representation
        let hg = Arc::new(inst.g.to_hypergraph());
        let mut hctx = Context::new(Preset::Default, 8, 0.03).with_threads(4).with_seed(2);
        hctx.contraction_limit_factor = 24;
        hctx.ip_min_repetitions = 2;
        hctx.ip_max_repetitions = 4;
        hctx.fm_max_rounds = 3;
        let t1 = Instant::now();
        let phg = partitioner::partition_arc(hg.clone(), &hctx);
        let hyper_secs = t1.elapsed().as_secs_f64();
        hyper_total.push(hyper_secs);

        for (pi, phase) in PHASES.iter().enumerate() {
            let gt = gctx.timer.get(phase).as_secs_f64();
            let ht = hctx.timer.get(phase).as_secs_f64();
            if gt > 0.0 && ht > 0.0 {
                phase_speedups[pi].push(ht / gt);
            }
        }
        // quality parity: edge cut on the graph partition vs km1 (== cut
        // for 2-pin nets) on the hypergraph partition
        let cut_graph = pg.cut();
        let cut_hyper = phg.km1();
        quality_rows.push(vec![
            inst.name.clone(),
            cut_graph.to_string(),
            cut_hyper.to_string(),
            format!("{:.2}x", hyper_secs / graph_secs.max(1e-12)),
        ]);
        // consistency: reported cut matches from-scratch computation
        assert_eq!(cut_graph, metrics::graph_cut(&inst.g, &pg.parts()));
    }

    benchkit::print_table(
        "Fig. 15 — quality parity + overall speedup of graph DS",
        &["instance", "cut (graph DS)", "cut (hypergraph DS)", "overall speedup"],
        &quality_rows,
    );
    let mut rows = vec![vec![
        "TOTAL".to_string(),
        format!(
            "{:.2}x",
            stats::geometric_mean(&hyper_total) / stats::geometric_mean(&graph_total).max(1e-12)
        ),
    ]];
    for (pi, phase) in PHASES.iter().enumerate() {
        if !phase_speedups[pi].is_empty() {
            rows.push(vec![
                phase.to_string(),
                format!("{:.2}x", stats::geometric_mean(&phase_speedups[pi])),
            ]);
        }
    }
    benchkit::print_table(
        "Fig. 15 — per-component speedup of the graph data structures",
        &["component", "speedup (hypergraph time / graph time)"],
        &rows,
    );
    println!(
        "\n=> paper expectation: coarsening benefits most (2.48x), FM least (1.29x), \
         overall 1.75x; quality unaffected."
    );
}
