//! Table 4 — geo-mean running times of Mt-KaHyPar-D / -Q-F with an
//! increasing number of threads vs the sequential baseline classes on
//! M_G and M_HG.

use mtkahypar::benchkit::{self, baselines, suites};
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::graph::partitioner::partition_graph_arc;
use mtkahypar::util::stats;
use std::time::Instant;

fn ctx_for(preset: Preset, k: usize, t: usize) -> Context {
    let mut ctx = Context::new(preset, k, 0.03).with_threads(t).with_seed(4);
    ctx.contraction_limit_factor = 24;
    ctx.ip_min_repetitions = 2;
    ctx.ip_max_repetitions = 4;
    ctx.fm_max_rounds = 3;
    ctx
}

fn main() {
    let k = 8;
    let threads = [1usize, 2, 4];

    // ------- hypergraphs (right half of Table 4) -------
    let hg_instances = suites::suite_mhg();
    let mut rows = Vec::new();
    // sequential baselines
    let mut patoh_times = Vec::new();
    for inst in &hg_instances {
        let start = Instant::now();
        let _ = baselines::patoh_like(&inst.hg, &ctx_for(Preset::Default, k, 1));
        patoh_times.push(start.elapsed().as_secs_f64());
    }
    rows.push(vec![
        "PaToH-like (seq)".into(),
        format!("{:.3}", stats::geometric_mean(&patoh_times)),
        "-".into(),
        "-".into(),
    ]);
    for preset in [Preset::Default, Preset::QualityFlows] {
        let mut row = vec![format!("{} ", preset.name())];
        for &t in &threads {
            let mut times = Vec::new();
            for inst in &hg_instances {
                let start = Instant::now();
                let _ = partitioner::partition_arc(inst.hg.clone(), &ctx_for(preset, k, t));
                times.push(start.elapsed().as_secs_f64());
            }
            row.push(format!("{:.3}", stats::geometric_mean(&times)));
        }
        rows.push(row);
    }
    benchkit::print_table(
        "Table 4 (M_HG) — geo-mean time [s] per thread count",
        &["algorithm", "t=1", "t=2", "t=4"],
        &rows,
    );

    // ------- graphs (left half of Table 4) -------
    let g_instances = suites::suite_mg();
    let mut grows = Vec::new();
    // Metis-class sequential baseline: graph pipeline, LP only, t=1
    let mut metis_times = Vec::new();
    for inst in &g_instances {
        let mut c = ctx_for(Preset::Default, k, 1);
        c.use_fm = false;
        c.use_community_detection = false;
        let start = Instant::now();
        let _ = partition_graph_arc(inst.g.clone(), &c);
        metis_times.push(start.elapsed().as_secs_f64());
    }
    grows.push(vec![
        "Metis-like (seq)".into(),
        format!("{:.3}", stats::geometric_mean(&metis_times)),
        "-".into(),
        "-".into(),
    ]);
    let mut row = vec!["Mt-KaHyPar-D (graph)".to_string()];
    for &t in &threads {
        let mut times = Vec::new();
        for inst in &g_instances {
            let start = Instant::now();
            let _ = partition_graph_arc(inst.g.clone(), &ctx_for(Preset::Default, k, t));
            times.push(start.elapsed().as_secs_f64());
        }
        row.push(format!("{:.3}", stats::geometric_mean(&times)));
    }
    grows.push(row);
    benchkit::print_table(
        "Table 4 (M_G) — geo-mean time [s] per thread count",
        &["algorithm", "t=1", "t=2", "t=4"],
        &grows,
    );
    println!(
        "\n=> paper expectation: Mt-KaHyPar-D matches PaToH-D speed at ~8 threads and \
         Metis-K at ~16; on this 1-vCPU testbed thread counts > 1 add overhead only."
    );
}
