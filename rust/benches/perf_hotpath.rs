//! §Perf — hot-path microbenchmarks driving the optimization pass
//! (EXPERIMENTS.md §Perf records before/after):
//!
//! * move-op throughput on the concurrent partition structure,
//! * gain-table update throughput,
//! * rating-map aggregation (coarsening inner loop),
//! * parallel contraction,
//! * n-level batch boundary: snapshot contraction vs in-place dynamic
//!   batch uncontraction (paper §9),
//! * parallel gain recalculation,
//! * one LP round,
//! * warm-start repartitioning (V-cycle apply) vs cold multilevel,
//! * AOT gain-tile execution + spectral execution (L1/L2 via PJRT).

use mtkahypar::coarsening::{project_partition, Level};
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::datastructures::RatingMap;
use mtkahypar::generators::{mesh_graph, planted_hypergraph, PlantedParams};
use mtkahypar::hypergraph::contraction;
use mtkahypar::hypergraph::dynamic::DynamicHypergraph;
use mtkahypar::partition::{
    recalculate_gains, GainTable, KStateMode, Move, PartitionPool, PartitionedHypergraph,
};
use mtkahypar::refinement::{flow, lp, Workspace};
use mtkahypar::repartition::{Change, ChangeBatch, RepartitionConfig, Repartitioner};
use mtkahypar::util::Rng;
use mtkahypar::{BlockId, NodeId};
use std::sync::Arc;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, per_iter_items: usize, mut f: F) {
    // warmup
    f();
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let total = start.elapsed().as_secs_f64();
    let per_item = total / (iters * per_iter_items.max(1)) as f64;
    println!(
        "{name:<42} {:>10.3} ms/iter   {:>9.1} ns/item",
        1e3 * total / iters as f64,
        1e9 * per_item
    );
}

fn main() {
    println!("perf_hotpath — ns/item hot-path microbenchmarks\n");
    let k = 8usize;
    let p = PlantedParams { n: 20_000, m: 36_000, blocks: k, ..Default::default() };
    let hg = Arc::new(planted_hypergraph(&p, 7));
    let n = hg.num_nodes();
    let parts: Vec<BlockId> = (0..n).map(|u| (u * k / n) as BlockId).collect();

    // ---- move op ----
    let mut phg = PartitionedHypergraph::new(hg.clone(), k);
    phg.set_uniform_max_weight(1.0);
    phg.assign_all(&parts, 1);
    let mut rng = Rng::new(1);
    let moves: Vec<(NodeId, BlockId)> =
        (0..5_000).map(|_| (rng.next_below(n) as NodeId, rng.next_below(k) as BlockId)).collect();
    bench("partition move op (attributed gains)", 20, moves.len(), || {
        for &(u, t) in &moves {
            if phg.block_of(u) != t {
                let _ = phg.try_move(u, t, None);
            }
        }
    });

    // ---- gain table updates ----
    let gt = GainTable::new(n, k);
    gt.initialize(&phg, 1);
    bench("move op + gain-table update rules", 10, moves.len(), || {
        for &(u, t) in &moves {
            if phg.block_of(u) != t {
                let _ = phg.try_move(u, t, Some(&gt));
            }
        }
    });
    bench("gain table full initialize", 5, n, || gt.initialize(&phg, 1));

    // ---- gain update: km1 generic vs pre-refactor shape ----
    // The GainPolicy refactor routes every move through monomorphized
    // generic code; `Km1Policy` must compile down to the pre-refactor
    // km1 update rules. The pair guards the zero-overhead claim: the
    // km1-named wrapper (the pre-refactor call shape) and the explicit
    // `try_move_p::<Km1Policy>` instantiation must run at the same
    // ns/item (objective dispatch happens once per refinement call,
    // never per move).
    bench("gain update: pre-refactor km1 shape", 10, moves.len(), || {
        for &(u, t) in &moves {
            if phg.block_of(u) != t {
                let _ = phg.try_move(u, t, Some(&gt));
            }
        }
    });
    bench("gain update: km1 via GainPolicy generic", 10, moves.len(), || {
        for &(u, t) in &moves {
            if phg.block_of(u) != t {
                let _ = phg.try_move_p::<mtkahypar::partition::Km1Policy>(u, t, Some(&gt));
            }
        }
    });

    // ---- refinement pipeline: per-level gain-table reuse ----
    // The uncoarsening loop runs refinement once per level. Before the
    // pipeline refactor each level paid GainTable::new (an O(n·k)
    // allocation + zeroing) on top of the value initialization; the
    // pipeline workspace allocates once and only re-initializes in place.
    let levels = 8;
    bench("gain table x8 levels: alloc + initialize", 3, levels * n, || {
        for _ in 0..levels {
            let fresh = GainTable::new(n, k);
            fresh.initialize(&phg, 1);
            std::hint::black_box(&fresh);
        }
    });
    let mut ws = Workspace::new(k, 1, n);
    bench("gain table x8 levels: pipeline reuse", 3, levels * n, || {
        for _ in 0..levels {
            ws.prepare_gain_table(&phg, 1);
        }
        std::hint::black_box(&ws);
    });
    assert_eq!(
        ws.gain_table_allocs(),
        1,
        "pipeline reuse must not allocate per level"
    );

    // ---- level build: alloc-per-level vs pooled rebind ----
    // One uncoarsening step = build the coarse level's partition, then
    // the fine level's from the projected assignment. The legacy path
    // pays two PartitionedHypergraph::new allocations, a parts()
    // snapshot and a projected Vec per step; the pooled path rebinds one
    // finest-level-sized allocation and projects Π in place.
    let half_rep: Vec<NodeId> = (0..n as NodeId).map(|u| u - (u % 2)).collect();
    let c2 = contraction::contract(&hg, &half_rep, 1);
    let coarse_hg = Arc::new(c2.coarse);
    let level =
        Level { coarse: coarse_hg.clone(), fine_to_coarse: c2.fine_to_coarse, net_map: c2.net_map };
    let coarse_n = coarse_hg.num_nodes();
    let coarse_parts: Vec<BlockId> =
        (0..coarse_n).map(|u| (u * k / coarse_n) as BlockId).collect();
    bench("level build x2: alloc + assign per level", 5, 2 * n, || {
        let mut cphg = PartitionedHypergraph::new(coarse_hg.clone(), k);
        cphg.set_uniform_max_weight(0.03);
        cphg.assign_all(&coarse_parts, 1);
        let fine_parts = project_partition(&level, &cphg.parts());
        let mut fphg = PartitionedHypergraph::new(hg.clone(), k);
        fphg.set_uniform_max_weight(0.03);
        fphg.assign_all(&fine_parts, 1);
        std::hint::black_box(&fphg);
    });
    let mut pool = PartitionPool::new(k);
    pool.reserve(&*hg);
    let mut bound = Some(pool.bind(coarse_hg.clone(), &coarse_parts, 0.03, 1));
    bench("level build x2: pooled in-place rebind", 5, 2 * n, || {
        let p = bound.take().unwrap();
        let p = pool.rebind_with_parts(p, coarse_hg.clone(), &coarse_parts, 0.03, 1);
        let p =
            pool.rebind_level(p, hg.clone(), &level.fine_to_coarse, Some(&level.net_map), 0.03, 1);
        std::hint::black_box(&p);
        bound = Some(p);
    });
    assert_eq!(
        pool.structural_allocs(),
        1,
        "pooled rebind must not allocate per level"
    );

    // ---- batch boundary: snapshot contract vs dynamic uncontract ----
    // One n-level batch boundary used to pay an O(n) union-find prefix
    // rebuild plus a full parallel contraction; the dynamic hypergraph
    // reverts the same batch by mutating pin-lists and incident-net
    // prefixes in place at O(batch) cost (paper §9).
    let mut dynhg = DynamicHypergraph::from_hypergraph(&hg);
    dynhg.reserve_events(hg.num_pins());
    let mut mementos = Vec::new();
    for u in (1..n as NodeId).step_by(2) {
        mementos.push(dynhg.contract(u, u - 1)); // pair odd onto even
    }
    let batch_at = mementos.len().saturating_sub(1024);
    // `mementos` keeps the prefix (stays contracted); `live` is the batch
    // reverted and re-applied per iteration, always using the mementos of
    // the *latest* re-contraction (recorded slots must match the current
    // event stack — never replay stale ones)
    let mut live: Vec<_> = mementos.split_off(batch_at);
    let batch_size = live.len();
    bench("batch boundary: snapshot contract", 5, batch_size, || {
        // the legacy path: union-find over the memento prefix + a full
        // static re-contraction of the input
        let mut rep_prefix: Vec<NodeId> = (0..n as NodeId).collect();
        for m in &mementos {
            rep_prefix[m.v as usize] = m.u;
        }
        for u in 0..n {
            let mut r = rep_prefix[u] as usize;
            while rep_prefix[r] as usize != r {
                r = rep_prefix[r] as usize;
            }
            rep_prefix[u] = r as NodeId;
        }
        let snap = contraction::contract(&hg, &rep_prefix, 1);
        std::hint::black_box(&snap.coarse);
    });
    // warm the uncontract/recontract cycle once so the counter below
    // captures the steady state
    let mut next = Vec::with_capacity(batch_size);
    dynhg.uncontract_batch(&live);
    for m in &live {
        next.push(dynhg.contract(m.v, m.u));
    }
    std::mem::swap(&mut live, &mut next);
    let dyn_grows = dynhg.structural_grows();
    bench("batch boundary: dynamic uncontract", 5, batch_size, || {
        dynhg.uncontract_batch(&live);
        next.clear();
        for m in &live {
            next.push(dynhg.contract(m.v, m.u));
        }
        std::mem::swap(&mut live, &mut next);
        std::hint::black_box(&dynhg);
    });
    assert_eq!(
        dynhg.structural_grows(),
        dyn_grows,
        "the dynamic batch boundary must not allocate"
    );

    // ---- flow refinement: fresh scratch vs pooled workspace ----
    // One flow_refine call per uncoarsening level used to reallocate the
    // quotient scaffolding, the per-pair flow networks and the FlowCutter
    // state; the workspace path sizes them once and reuses the memory.
    let kf = 4usize;
    let pf = PlantedParams { n: 2000, m: 4000, blocks: kf, ..Default::default() };
    let fhg = Arc::new(planted_hypergraph(&pf, 23));
    let nf = fhg.num_nodes();
    let mut rngf = Rng::new(41);
    let mut fparts: Vec<BlockId> = (0..nf).map(|u| (u * kf / nf) as BlockId).collect();
    for _ in 0..nf / 20 {
        fparts[rngf.next_below(nf)] = rngf.next_below(kf) as BlockId;
    }
    let fctx = Context::new(Preset::DefaultFlows, kf, 0.1).with_threads(1).with_seed(7);
    let fphg = {
        let mut p = PartitionedHypergraph::new(fhg.clone(), kf);
        p.set_uniform_max_weight(0.1);
        p
    };
    bench("flow refine: fresh scratch per call", 3, nf, || {
        fphg.assign_all(&fparts, 1);
        let _ = flow::flow_refine(&fphg, &fctx);
    });
    let mut fw = flow::FlowWorkspace::new(kf);
    fphg.assign_all(&fparts, 1);
    let _ = flow::flow_refine_with_workspace(&fphg, &fctx, &mut fw);
    let flow_allocs = fw.structural_allocs();
    bench("flow refine: pooled workspace reuse", 3, nf, || {
        fphg.assign_all(&fparts, 1);
        let _ = flow::flow_refine_with_workspace(&fphg, &fctx, &mut fw);
    });
    assert_eq!(
        fw.structural_allocs(),
        flow_allocs,
        "pooled flow refinement must not allocate after the first call"
    );

    // ---- rating map (coarsening inner loop) ----
    let mut map = RatingMap::with_default_capacity();
    bench("rating-map aggregation over pins", 10, hg.num_pins(), || {
        for u in 0..n as NodeId {
            map.clear();
            for &e in hg.incident_nets(u) {
                let r = hg.net_weight(e) as f64 / (hg.net_size(e).max(2) - 1) as f64;
                for &v in hg.pins(e) {
                    if v != u {
                        map.add(v as u64, r);
                    }
                }
            }
        }
    });

    // ---- contraction ----
    let rep: Vec<NodeId> = (0..n as NodeId).map(|u| u - (u % 2)).collect();
    bench("parallel contraction (2:1 clustering)", 5, hg.num_pins(), || {
        let _ = contraction::contract(&hg, &rep, 1);
    });

    // ---- gain recalculation ----
    let phg2 = PartitionedHypergraph::new(hg.clone(), k);
    phg2.assign_all(&parts, 1);
    let mut seq_moves: Vec<Move> = Vec::new();
    let mut rng2 = Rng::new(9);
    for u in rng2.sample_indices(n, 2_000) {
        let from = phg2.block_of(u as NodeId);
        let to = ((from as usize + 1) % k) as BlockId;
        phg2.move_unchecked(u as NodeId, to, None);
        seq_moves.push(Move { node: u as NodeId, from, to });
    }
    bench("parallel gain recalculation (Alg 6.2)", 10, seq_moves.len(), || {
        let _ = recalculate_gains(&phg2, &seq_moves, 1);
    });

    // ---- LP round ----
    let mut ctx = Context::new(Preset::Speed, k, 0.03).with_threads(1).with_seed(3);
    ctx.lp_rounds = 1;
    let phg3 = PartitionedHypergraph::new(hg.clone(), k);
    phg3.assign_all(&parts, 1);
    bench("one LP round over all nodes", 5, n, || {
        let _ = lp::lp_refine(&phg3, &ctx);
    });

    // ---- graph refine: hypergraph-shaped state vs CSR two-pin kernels ----
    // The same plain graph refined through both PartitionState backends.
    // The hypergraph-shaped run materializes the topology as two-pin nets
    // and pays Φ pin-count arrays plus Λ connectivity sets; the Graph
    // instantiation keeps one packed endpoint-block word per undirected
    // edge and recomputes gains in a single CSR adjacency scan.
    let gm = mesh_graph(64, 64);
    let gk = 4usize;
    let gn = gm.num_nodes();
    let gparts: Vec<BlockId> = (0..gn).map(|u| (u * gk / gn) as BlockId).collect();
    let mut gctx = Context::new(Preset::Speed, gk, 0.05).with_threads(1).with_seed(5);
    gctx.lp_rounds = 2;
    let ghg = Arc::new(gm.to_hypergraph());
    let mut hview = PartitionedHypergraph::new(ghg, gk);
    hview.set_uniform_max_weight(0.05);
    bench("graph refine: hypergraph-shaped state", 5, gn, || {
        hview.assign_all(&gparts, 1);
        let _ = lp::lp_refine(&hview, &gctx);
    });
    let garc = Arc::new(gm);
    let pins_before = mtkahypar::partition::pin_counts::allocation_count();
    let conn_before = mtkahypar::partition::connectivity::allocation_count();
    let mut gview = mtkahypar::partition::PartitionedGraph::new(garc, gk);
    gview.set_uniform_max_weight(0.05);
    bench("graph refine: CSR two-pin kernels", 5, gn, || {
        gview.assign_all(&gparts, 1);
        let _ = lp::lp_refine(&gview, &gctx);
    });
    assert_eq!(
        mtkahypar::partition::pin_counts::allocation_count(),
        pins_before,
        "the graph path must never allocate a pin-count array"
    );
    assert_eq!(
        mtkahypar::partition::connectivity::allocation_count(),
        conn_before,
        "the graph path must never allocate connectivity sets"
    );

    // ---- large-k layer: dense O(n·k)/O(m·k) state vs SparseKState ----
    // At k = 128 the dense layout pays k-proportional initialization and
    // memory (packed Φ arrays, Λ bitsets, (k+1)·n gain-table words); the
    // sparse layout keeps per-net (block → count) mini-tables sized by
    // min(|e|, k) and a gain cache holding only the penalty entries for
    // blocks in Λ(I(u)), so both init and update costs follow locality,
    // not k. The counters pin the memory claim: the sparse run must never
    // allocate a packed pin-count array or a connectivity bitset, and the
    // whole run (init + 5k moves) performs exactly one arena allocation.
    let bk = 128usize;
    let bp = PlantedParams { n: 6_000, m: 11_000, blocks: bk, ..Default::default() };
    let bhg = Arc::new(planted_hypergraph(&bp, 77));
    let bn = bhg.num_nodes();
    let bparts: Vec<BlockId> = (0..bn).map(|u| (u * bk / bn) as BlockId).collect();
    let mut brng = Rng::new(13);
    let bmoves: Vec<(NodeId, BlockId)> = (0..5_000)
        .map(|_| (brng.next_below(bn) as NodeId, brng.next_below(bk) as BlockId))
        .collect();

    let mut dense_phg = PartitionedHypergraph::new_with_mode(bhg.clone(), bk, KStateMode::Dense);
    dense_phg.set_uniform_max_weight(1.0);
    dense_phg.assign_all(&bparts, 1);
    let dense_gt = GainTable::with_mode(bn, bk, KStateMode::Dense);
    bench("gain init k=128: dense O(n*k)", 5, bn, || dense_gt.initialize(&dense_phg, 1));

    let pins_before = mtkahypar::partition::pin_counts::allocation_count();
    let conn_before = mtkahypar::partition::connectivity::allocation_count();
    let arena_before = mtkahypar::partition::sparse_state::allocation_count();
    let mut sparse_phg = PartitionedHypergraph::new_with_mode(bhg.clone(), bk, KStateMode::Sparse);
    sparse_phg.set_uniform_max_weight(1.0);
    sparse_phg.assign_all(&bparts, 1);
    let sparse_gt = GainTable::with_mode(bn, bk, KStateMode::Sparse);
    bench("gain init k=128: sparse O(pins)", 5, bn, || sparse_gt.initialize(&sparse_phg, 1));

    bench("phi/lambda update k=128: packed (dense)", 10, bmoves.len(), || {
        for &(u, t) in &bmoves {
            if dense_phg.block_of(u) != t {
                let _ = dense_phg.try_move(u, t, Some(&dense_gt));
            }
        }
    });
    bench("phi/lambda update k=128: hashed (sparse)", 10, bmoves.len(), || {
        for &(u, t) in &bmoves {
            if sparse_phg.block_of(u) != t {
                let _ = sparse_phg.try_move(u, t, Some(&sparse_gt));
            }
        }
    });
    assert_eq!(
        mtkahypar::partition::pin_counts::allocation_count(),
        pins_before,
        "the sparse large-k path must never allocate a packed pin-count array"
    );
    assert_eq!(
        mtkahypar::partition::connectivity::allocation_count(),
        conn_before,
        "the sparse large-k path must never allocate connectivity bitsets"
    );
    assert_eq!(
        mtkahypar::partition::sparse_state::allocation_count(),
        arena_before + 1,
        "one arena allocation for the whole sparse run — init and moves reuse it"
    );

    // ---- repartitioning: warm V-cycle serving vs cold multilevel ----
    {
        use mtkahypar::hypergraph::HypergraphOps;
        let rk = 4usize;
        let rp = PlantedParams { n: 4_000, m: 7_000, blocks: rk, ..Default::default() };
        let rhg = Arc::new(planted_hypergraph(&rp, 11));
        let mut rctx = Context::new(Preset::Default, rk, 0.05).with_seed(11).with_threads(1);
        rctx.contraction_limit_factor = 24;
        rctx.ip_min_repetitions = 1;
        rctx.ip_max_repetitions = 2;
        rctx.fm_max_rounds = 2;
        let mut rep = Repartitioner::new(rhg.clone(), rctx.clone(), RepartitionConfig::default());
        assert_eq!(rep.partition_pool().structural_allocs(), 1, "one session bind");
        let mut crng = Rng::new(13);
        bench("repartition: warm V-cycle apply", 10, 4, || {
            // slot-reusing churn: one node and one net out, equivalents in
            let (victim_node, victim_net, victim_size, pins) = {
                let hgd = rep.hypergraph();
                let active: Vec<NodeId> = hgd.active_nodes().collect();
                let victim_node = active[crng.next_below(active.len())];
                let e = hgd
                    .nets()
                    .max_by_key(|&e| HypergraphOps::pins(hgd, e).len())
                    .expect("instance has nets");
                let size = HypergraphOps::pins(hgd, e).len();
                let pins: Vec<NodeId> = crng
                    .sample_indices(active.len(), size)
                    .into_iter()
                    .map(|i| active[i])
                    .filter(|&u| u != victim_node)
                    .take(size.saturating_sub(1).max(1))
                    .collect();
                (victim_node, e, size, pins)
            };
            assert!(victim_size >= 2);
            let mut batch = ChangeBatch::new();
            batch.push(Change::RemoveNet { net: victim_net });
            batch.push(Change::RemoveNode { node: victim_node });
            batch.push(Change::InsertNode { weight: 1 });
            batch.push(Change::InsertNet { pins, weight: 1 });
            let ms = rep.apply(&batch).expect("churn batch applies");
            assert!(ms.balanced);
        });
        // the acceptance criterion of the serving path, asserted on the
        // pool counters: every warm apply above ran allocation-free
        assert_eq!(
            rep.partition_pool().structural_allocs(),
            1,
            "warm V-cycle applies must make zero structural allocations"
        );
        bench("repartition: cold multilevel baseline", 10, 4, || {
            let cold = mtkahypar::coordinator::partitioner::partition_arc(rhg.clone(), &rctx);
            assert!(cold.is_balanced());
        });
    }

    // ---- runtime (L1/L2 via PJRT) ----
    if let Some(rt) = mtkahypar::runtime::global() {
        let a = vec![0.25f32; 128 * 128];
        let w = vec![1f32; 128];
        let mut x = vec![0f32; 128 * 16];
        for i in 0..128 {
            x[i * 16 + i % 8] = 1.0;
        }
        bench("AOT gain-tile execution (128x128x16)", 20, 128 * 128, || {
            let _ = rt.gain_tiles(&a, &w, &x).unwrap();
        });
        let adj = vec![0.01f32; 256 * 256];
        let deg = vec![2.56f32; 256];
        bench("AOT spectral power iteration (256)", 5, 256 * 256, || {
            let _ = rt.spectral(&adj, &deg).unwrap();
        });
    } else {
        println!("(runtime artifacts missing — run `make artifacts` for the AOT benches)");
    }
}
