//! Fig. 11 — share of each algorithmic component on the total execution
//! time, per configuration, on the L_HG suite.

use mtkahypar::benchkit::{self, suites};
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::util::stats;
use std::collections::BTreeMap;

fn main() {
    let instances = suites::suite_lhg();
    let presets =
        [Preset::Deterministic, Preset::Default, Preset::DefaultFlows, Preset::Quality];
    for preset in presets {
        // shares collected per component across instances
        let mut shares: BTreeMap<&'static str, Vec<f64>> = BTreeMap::new();
        for inst in &instances {
            let mut ctx = Context::new(preset, 8, 0.03).with_threads(4).with_seed(1);
            ctx.contraction_limit_factor = 24;
            ctx.ip_min_repetitions = 2;
            ctx.ip_max_repetitions = 4;
            ctx.fm_max_rounds = 4;
            let _ = partitioner::partition_arc(inst.hg.clone(), &ctx);
            for (name, share) in ctx.timer.shares() {
                shares.entry(name).or_default().push(share);
            }
        }
        let rows: Vec<Vec<String>> = shares
            .iter()
            .map(|(name, vals)| {
                vec![
                    name.to_string(),
                    format!("{:.1}%", 100.0 * stats::median(vals)),
                    format!("{:.1}%", 100.0 * vals.iter().cloned().fold(f64::MIN, f64::max)),
                ]
            })
            .collect();
        benchkit::print_table(
            &format!("Fig. 11 — component time shares, {}", preset.name()),
            &["component", "median share", "max share"],
            &rows,
        );
    }
    println!(
        "\n=> paper expectation: D dominated by preprocessing/coarsening/FM (~21-23% each); \
         SDet by preprocessing+coarsening; D-F by flows (77.8% median); Q by coarsening/\
         batch-uncontractions/localized FM."
    );
}
