//! Fig. 9 — performance profiles + relative running times of the five
//! Mt-KaHyPar configurations on the M_HG suite.

use mtkahypar::benchkit::{self, profiles, suites};
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::util::stats;

fn main() {
    let instances = suites::suite_mhg();
    let seeds = [0u64, 1, 2];
    let ks = [2usize, 8];
    let presets = [
        Preset::Deterministic,
        Preset::Default,
        Preset::Quality,
        Preset::DefaultFlows,
        Preset::QualityFlows,
    ];

    let mut results = Vec::new();
    for inst in &instances {
        for &k in &ks {
            for preset in presets {
                for &seed in &seeds {
                    let mut ctx = Context::new(preset, k, 0.03).with_threads(4).with_seed(seed);
                    ctx.contraction_limit_factor = 24;
                    ctx.ip_min_repetitions = 2;
                    ctx.ip_max_repetitions = 5;
                    ctx.fm_max_rounds = 4;
                    results.push(benchkit::run_hg(
                        preset.name(),
                        &inst.hg,
                        &format!("{}_k{k}", inst.name),
                        &ctx,
                    ));
                }
            }
        }
    }
    let agg = benchkit::aggregate_seeds(&results);
    let taus = profiles::default_taus();
    let lines = profiles::performance_profiles(&agg, &taus);

    let mut rows = Vec::new();
    for line in &lines {
        let mut row = vec![line.algorithm.clone()];
        row.extend(line.points.iter().map(|&(_, f)| format!("{f:.2}")));
        row.push(format!("{:.2}", line.infeasible_fraction));
        rows.push(row);
    }
    let mut header = vec!["algorithm".to_string()];
    header.extend(taus.iter().map(|t| format!("τ={t}")));
    header.push("infeas".into());
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    benchkit::print_table("Fig. 9 — performance profiles (fraction ≤ τ·best)", &header_refs, &rows);

    // relative running times (paper ranking: SDet/D fast, Q ≈ D-F, Q-F slowest)
    let d_time = stats::geometric_mean(
        &agg.iter()
            .filter(|r| r.algorithm == "Mt-KaHyPar-D")
            .map(|r| r.seconds)
            .collect::<Vec<_>>(),
    );
    let mut time_rows = Vec::new();
    for preset in presets {
        let times: Vec<f64> =
            agg.iter().filter(|r| r.algorithm == preset.name()).map(|r| r.seconds).collect();
        let g = stats::geometric_mean(&times);
        time_rows.push(vec![
            preset.name().to_string(),
            format!("{g:.3}"),
            format!("{:.2}x", g / d_time.max(1e-12)),
        ]);
    }
    benchkit::print_table(
        "Fig. 9 — geo-mean running times (relative to Mt-KaHyPar-D)",
        &["configuration", "time [s]", "vs D"],
        &time_rows,
    );
}
