//! Fig. 10 — effectiveness tests: Mt-KaHyPar-D vs -Q and -D-F vs -Q-F
//! with equal time budgets (the faster algorithm gets extra repetitions).

use mtkahypar::benchkit::{self, profiles, suites};
use mtkahypar::coordinator::context::{Context, Preset};

fn run_preset(
    preset: Preset,
    inst: &suites::HgInstance,
    k: usize,
    seeds: &[u64],
) -> Vec<benchkit::RunResult> {
    seeds
        .iter()
        .map(|&seed| {
            let mut ctx = Context::new(preset, k, 0.03).with_threads(4).with_seed(seed);
            ctx.contraction_limit_factor = 24;
            ctx.ip_min_repetitions = 2;
            ctx.ip_max_repetitions = 4;
            ctx.fm_max_rounds = 4;
            benchkit::run_hg(preset.name(), &inst.hg, &inst.name, &ctx)
        })
        .collect()
}

fn compare(pa: Preset, pb: Preset, instances: &[suites::HgInstance], k: usize) {
    let seeds: Vec<u64> = (0..5).collect();
    let mut wins_a = 0usize;
    let mut wins_b = 0usize;
    let mut ties = 0usize;
    let mut rows = Vec::new();
    for inst in instances {
        let runs_a = run_preset(pa, inst, k, &seeds);
        let runs_b = run_preset(pb, inst, k, &seeds);
        let ra: Vec<&benchkit::RunResult> = runs_a.iter().collect();
        let rb: Vec<&benchkit::RunResult> = runs_b.iter().collect();
        let pairs = profiles::effectiveness_pairs(&ra, &rb, 10, 42);
        let (mut a, mut b, mut t) = (0, 0, 0);
        for (qa, qb) in &pairs {
            match qa.cmp(qb) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => t += 1,
            }
        }
        wins_a += a;
        wins_b += b;
        ties += t;
        rows.push(vec![inst.name.clone(), a.to_string(), b.to_string(), t.to_string()]);
    }
    rows.push(vec!["TOTAL".into(), wins_a.to_string(), wins_b.to_string(), ties.to_string()]);
    benchkit::print_table(
        &format!("Fig. 10 — effectiveness test {} vs {} (virtual-instance wins)", pa.name(), pb.name()),
        &["instance", &format!("{} wins", pa.name()), &format!("{} wins", pb.name()), "ties"],
        &rows,
    );
    let total = (wins_a + wins_b + ties).max(1) as f64;
    println!(
        "=> paper expectation: near-parity once time-normalized. Measured split: {:.0}% / {:.0}% / {:.0}% (A/B/tie)",
        100.0 * wins_a as f64 / total,
        100.0 * wins_b as f64 / total,
        100.0 * ties as f64 / total
    );
}

fn main() {
    let instances: Vec<_> = suites::suite_mhg().into_iter().take(5).collect();
    compare(Preset::Default, Preset::Quality, &instances, 8);
    compare(Preset::DefaultFlows, Preset::QualityFlows, &instances, 8);
}
