//! Fig. 14 — solution quality with an increasing number of threads:
//! more threads must not (systematically) degrade quality, and SDet must
//! stay bit-identical.

use mtkahypar::benchkit::{self, profiles, suites};
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;

fn main() {
    let instances = suites::suite_lhg();
    let presets = [Preset::Deterministic, Preset::Default, Preset::DefaultFlows];
    let threads = [1usize, 4];

    let mut results = Vec::new();
    let mut det_identical = true;
    for inst in &instances {
        let mut det_parts: Option<Vec<u32>> = None;
        for preset in presets {
            for &t in &threads {
                let mut ctx = Context::new(preset, 8, 0.03).with_threads(t).with_seed(5);
                ctx.contraction_limit_factor = 24;
                ctx.ip_min_repetitions = 2;
                ctx.ip_max_repetitions = 4;
                ctx.fm_max_rounds = 3;
                let phg = partitioner::partition_arc(inst.hg.clone(), &ctx);
                if preset == Preset::Deterministic {
                    match &det_parts {
                        None => det_parts = Some(phg.parts()),
                        Some(p) => det_identical &= *p == phg.parts(),
                    }
                }
                results.push(benchkit::RunResult {
                    algorithm: format!("{} t={t}", preset.name()),
                    instance: inst.name.clone(),
                    k: 8,
                    quality: phg.km1(),
                    imbalance: phg.imbalance(),
                    feasible: phg.is_balanced(),
                    seconds: 0.0,
                });
            }
        }
        det_parts = None;
        let _ = det_parts;
    }
    let taus = profiles::default_taus();
    let lines = profiles::performance_profiles(&results, &taus);
    let mut rows = Vec::new();
    for line in &lines {
        let mut row = vec![line.algorithm.clone()];
        row.extend(line.points.iter().map(|&(_, f)| format!("{f:.2}")));
        rows.push(row);
    }
    let mut header = vec!["algorithm".to_string()];
    header.extend(taus.iter().map(|t| format!("τ={t}")));
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    benchkit::print_table(
        "Fig. 14 — quality vs thread count (performance profiles)",
        &header_refs,
        &rows,
    );
    println!(
        "\nSDet bit-identical across thread counts: {det_identical} (paper requirement: true)"
    );
}
