//! Figs. 17 & 19 + Table 3 (graph part) — graph partitioning: the
//! graph-optimized Mt-KaHyPar-D vs the fast LP-only class
//! (KaMinPar/Metis-like) and the parallel-FM class (Mt-KaHIP-like).

use mtkahypar::benchkit::{self, suites};
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::graph::partitioner::partition_graph_arc;
use mtkahypar::metrics;
use mtkahypar::util::stats;
use std::time::Instant;

struct Row {
    name: &'static str,
    quality: Vec<f64>,
    time: Vec<f64>,
}

fn run(
    name: &'static str,
    instances: &[suites::GraphInstance],
    k: usize,
    configure: impl Fn(&mut Context),
) -> Row {
    let mut quality = Vec::new();
    let mut time = Vec::new();
    for inst in instances {
        let mut ctx = Context::new(Preset::Default, k, 0.03).with_threads(4).with_seed(9);
        ctx.contraction_limit_factor = 24;
        ctx.ip_min_repetitions = 2;
        ctx.ip_max_repetitions = 4;
        ctx.fm_max_rounds = 3;
        configure(&mut ctx);
        let start = Instant::now();
        let pg = partition_graph_arc(inst.g.clone(), &ctx);
        time.push(start.elapsed().as_secs_f64());
        assert_eq!(pg.cut(), metrics::graph_cut(&inst.g, &pg.parts()));
        quality.push(pg.cut() as f64 + 1.0);
    }
    Row { name, quality, time }
}

fn main() {
    for (suite_name, instances, k) in
        [("M_G (Fig. 17)", suites::suite_mg(), 8), ("L_G (Fig. 19)", suites::suite_lg(), 8)]
    {
        let algos = vec![
            run("Mt-KaHyPar-D (graph)", &instances, k, |_| {}),
            run("Mt-KaHyPar-S (graph)", &instances, k, |c| c.use_fm = false),
            // KaMinPar/Metis class: LP only, no community detection
            run("KaMinPar-like", &instances, k, |c| {
                c.use_fm = false;
                c.use_community_detection = false;
                c.lp_rounds = 3;
            }),
            // Mt-KaHIP class: FM but no community-aware coarsening
            run("Mt-KaHIP-like", &instances, k, |c| {
                c.use_community_detection = false;
            }),
        ];
        let mut rows = Vec::new();
        for a in &algos {
            let base = &algos[0];
            let improvements: Vec<f64> = base
                .quality
                .iter()
                .zip(&a.quality)
                .map(|(b, o)| (o / b - 1.0) * 100.0)
                .collect();
            let (z, p) = stats::wilcoxon_signed_rank(&base.quality, &a.quality);
            rows.push(vec![
                a.name.to_string(),
                format!("{:.3}", stats::geometric_mean(&a.time)),
                format!("{:.1}%", stats::median(&improvements)),
                format!("{z:.2}"),
                format!("{p:.4}"),
            ]);
        }
        benchkit::print_table(
            &format!("Figs. 17/19 + Table 3 — graph comparison on {suite_name}"),
            &["algorithm", "geo time [s]", "median edge-cut excess vs Mt-D", "Z", "p"],
            &rows,
        );
    }
    println!(
        "\n=> paper expectations: KaMinPar-class is fastest but ~9.9% worse cuts; \
         Mt-KaHyPar-D beats Mt-KaHIP-class by ~2.1% while being slightly faster."
    );
}
