//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! * community-aware coarsening on/off (paper §4.3's claimed quality win),
//! * the refinement stack tier by tier (LP → +FM → +flows, Alg. 3.1's
//!   rationale "increasingly better solution quality at higher cost"),
//! * portfolio breadth (1 technique vs all nine, §5),
//! * V-cycles as post-processing (§4.3's alternative),
//! * bulk piercing on/off is implicit in flows' runtime (cutter warm-up),
//! * the deterministic tier: the paper's SDet (det-LP only) vs our
//!   Deterministic preset (det-LP → det-FM, §11) — the quality the
//!   synchronous FM buys back while keeping bit-identity.

use mtkahypar::benchkit::{self, suites};
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::refinement::vcycle;
use mtkahypar::util::stats;
use std::time::Instant;

fn base_ctx(seed: u64) -> Context {
    let mut ctx = Context::new(Preset::Default, 8, 0.03).with_threads(4).with_seed(seed);
    ctx.contraction_limit_factor = 24;
    ctx.ip_min_repetitions = 2;
    ctx.ip_max_repetitions = 4;
    ctx.fm_max_rounds = 4;
    ctx
}

fn det_ctx(seed: u64) -> Context {
    let mut ctx = Context::new(Preset::Deterministic, 8, 0.03).with_threads(4).with_seed(seed);
    ctx.contraction_limit_factor = 24;
    ctx.ip_min_repetitions = 2;
    ctx.ip_max_repetitions = 4;
    ctx.fm_max_rounds = 4;
    ctx
}

fn main() {
    let instances = suites::suite_mhg();
    let variants: Vec<(&str, Box<dyn Fn(u64) -> Context>)> = vec![
        ("D (full)", Box::new(base_ctx)),
        (
            "D − community detection",
            Box::new(|s| {
                let mut c = base_ctx(s);
                c.use_community_detection = false;
                c
            }),
        ),
        (
            "LP only (no FM)",
            Box::new(|s| {
                let mut c = base_ctx(s);
                c.use_fm = false;
                c
            }),
        ),
        (
            "D + flows",
            Box::new(|s| {
                let mut c = base_ctx(s);
                c.use_flows = true;
                c
            }),
        ),
        (
            "D, portfolio = 1 rep",
            Box::new(|s| {
                let mut c = base_ctx(s);
                c.ip_min_repetitions = 1;
                c.ip_max_repetitions = 1;
                c
            }),
        ),
        (
            "SDet (paper: det-LP only)",
            Box::new(|s| {
                let mut c = det_ctx(s);
                c.use_fm = false;
                c
            }),
        ),
        ("SDet + det-FM (our Deterministic)", Box::new(det_ctx)),
    ];

    let mut rows = Vec::new();
    let mut base_quality: Vec<f64> = Vec::new();
    for (name, mk) in &variants {
        let mut km1s = Vec::new();
        let mut times = Vec::new();
        for inst in &instances {
            let ctx = mk(3);
            let start = Instant::now();
            let phg = partitioner::partition_arc(inst.hg.clone(), &ctx);
            times.push(start.elapsed().as_secs_f64());
            assert!(phg.is_balanced(), "{name} on {}", inst.name);
            km1s.push(phg.km1() as f64 + 1.0);
        }
        if base_quality.is_empty() {
            base_quality = km1s.clone();
        }
        let rel: Vec<f64> =
            km1s.iter().zip(&base_quality).map(|(a, b)| a / b).collect();
        rows.push(vec![
            name.to_string(),
            format!("{:.0}", stats::geometric_mean(&km1s)),
            format!("{:+.1}%", 100.0 * (stats::geometric_mean(&rel) - 1.0)),
            format!("{:.2}", stats::geometric_mean(&times)),
        ]);
    }

    // V-cycle post-processing ablation
    {
        let mut km1s = Vec::new();
        let mut times = Vec::new();
        for inst in &instances {
            let ctx = base_ctx(3);
            let start = Instant::now();
            let phg = partitioner::partition_arc(inst.hg.clone(), &ctx);
            let improved = vcycle(phg, &ctx, 1);
            times.push(start.elapsed().as_secs_f64());
            km1s.push(improved.km1() as f64 + 1.0);
        }
        let rel: Vec<f64> = km1s.iter().zip(&base_quality).map(|(a, b)| a / b).collect();
        rows.push(vec![
            "D + 1 V-cycle".to_string(),
            format!("{:.0}", stats::geometric_mean(&km1s)),
            format!("{:+.1}%", 100.0 * (stats::geometric_mean(&rel) - 1.0)),
            format!("{:.2}", stats::geometric_mean(&times)),
        ]);
    }

    benchkit::print_table(
        "Ablations — component contribution to Mt-KaHyPar-D (M_HG, k=8)",
        &["variant", "geo-mean km1", "vs full D", "geo time [s]"],
        &rows,
    );
    println!(
        "\n=> expectations: removing community detection and FM hurt quality; flows and \
         V-cycles improve it at extra cost; a 1-rep portfolio is faster but worse \
         (paper §4.3/§5 and the V-cycle discussion: ~2× runtime for post-processing). \
         The deterministic pair isolates det-FM: SDet+det-FM must close most of the \
         LP-only gap to D while both SDet rows stay bit-identical across thread counts."
    );
}
