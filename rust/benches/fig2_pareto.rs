//! Fig. 2 — the time/quality landscape of partitioning algorithms, plus
//! the Fig. 8 benchmark-set statistics.
//!
//! For every solver we compute per-instance quality ratios relative to
//! the best solver on that instance, aggregate with the harmonic mean
//! (paper's y-axis), and geometric-mean running times (x-axis). Markers
//! toward the lower left are better; Mt-KaHyPar configurations should
//! occupy the Pareto frontier spanned by the internal baselines.

use mtkahypar::benchkit::{self, baselines, suites};
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::util::stats;
use std::time::Instant;

type AlgoFn = Box<dyn Fn(&suites::HgInstance, u64) -> benchkit::RunResult>;

fn bench_ctx(preset: Preset, k: usize, threads: usize, seed: u64) -> Context {
    let mut ctx = Context::new(preset, k, 0.03).with_threads(threads).with_seed(seed);
    ctx.contraction_limit_factor = 24;
    ctx.ip_min_repetitions = 2;
    ctx.ip_max_repetitions = 5;
    ctx.fm_max_rounds = 4;
    ctx
}

fn preset_algo(
    name: &'static str,
    preset: Preset,
    k: usize,
    threads: usize,
) -> (&'static str, AlgoFn) {
    (
        name,
        Box::new(move |inst, seed| {
            let ctx = bench_ctx(preset, k, threads, seed);
            benchkit::run_hg(name, &inst.hg, &inst.name, &ctx)
        }),
    )
}

fn baseline_algo(
    name: &'static str,
    k: usize,
    threads: usize,
    f: impl Fn(
            &std::sync::Arc<mtkahypar::hypergraph::Hypergraph>,
            &Context,
        ) -> mtkahypar::partition::PartitionedHypergraph
        + 'static,
) -> (&'static str, AlgoFn) {
    (
        name,
        Box::new(move |inst, seed| {
            let ctx = bench_ctx(Preset::Default, k, threads, seed);
            let start = Instant::now();
            let phg = f(&inst.hg, &ctx);
            benchkit::RunResult {
                algorithm: name.to_string(),
                instance: inst.name.clone(),
                k,
                quality: phg.km1(),
                imbalance: phg.imbalance(),
                feasible: phg.is_balanced(),
                seconds: start.elapsed().as_secs_f64(),
            }
        }),
    )
}

fn main() {
    let instances = suites::suite_mhg();
    suites::print_suite_stats(&instances);
    let k = 8;
    let threads = 4;

    let algos: Vec<(&str, AlgoFn)> = vec![
        preset_algo("Mt-KaHyPar-S", Preset::Speed, k, threads),
        preset_algo("Mt-KaHyPar-D", Preset::Default, k, threads),
        preset_algo("Mt-KaHyPar-D-F", Preset::DefaultFlows, k, threads),
        preset_algo("Mt-KaHyPar-Q", Preset::Quality, k, threads),
        preset_algo("Mt-KaHyPar-Q-F", Preset::QualityFlows, k, threads),
        preset_algo("Mt-KaHyPar-SDet", Preset::Deterministic, k, threads),
        baseline_algo("PaToH-like", k, threads, baselines::patoh_like),
        baseline_algo("Zoltan-like", k, threads, baselines::zoltan_like),
        baseline_algo("BiPart-like", k, threads, baselines::bipart_like),
        baseline_algo("flat-LP", k, threads, baselines::flat_lp),
    ];
    let mut results: Vec<benchkit::RunResult> = Vec::new();
    for inst in &instances {
        for (_, run) in &algos {
            results.push(run(inst, 0));
        }
    }

    let mut names: Vec<String> = results.iter().map(|r| r.algorithm.clone()).collect();
    names.sort();
    names.dedup();
    let mut rows = Vec::new();
    for name in &names {
        let mine: Vec<&benchkit::RunResult> =
            results.iter().filter(|r| &r.algorithm == name).collect();
        let ratios: Vec<f64> = mine
            .iter()
            .map(|r| {
                let best = results
                    .iter()
                    .filter(|o| o.instance == r.instance && o.feasible)
                    .map(|o| o.quality)
                    .min()
                    .unwrap_or(r.quality)
                    .max(1);
                r.quality.max(1) as f64 / best as f64
            })
            .collect();
        let times: Vec<f64> = mine.iter().map(|r| r.seconds).collect();
        let infeasible = mine.iter().filter(|r| !r.feasible).count();
        rows.push(vec![
            name.clone(),
            format!("{:.4}", stats::harmonic_mean(&ratios)),
            format!("{:.3}", stats::geometric_mean(&times)),
            format!("{infeasible}/{}", mine.len()),
        ]);
    }
    benchkit::print_table(
        "Fig. 2 analogue — quality ratio (harmonic mean, lower=better) vs geo-mean time [s]",
        &["algorithm", "quality ratio", "time [s]", "infeasible"],
        &rows,
    );
}
