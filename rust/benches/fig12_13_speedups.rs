//! Figs. 12/13 + Table 1 — self-relative speedups of total time and of
//! the phases (preprocessing, coarsening, initial partitioning,
//! uncoarsening) with t ∈ {1, 2, 4}.
//!
//! TESTBED GATE: this container exposes a single vCPU, so wall-clock
//! speedups are expected to hover near 1.0 (threading overhead visible
//! instead of speedup). The harness nevertheless runs the full
//! multi-threaded code paths and reports the parallel-overhead ratio —
//! see EXPERIMENTS.md for the interpretation against the paper's
//! 64-core numbers.

use mtkahypar::benchkit::{self, suites};
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::util::stats;
use std::time::Instant;

const PHASES: [&str; 4] =
    ["preprocessing", "coarsening", "initial_partitioning", "fm"];

fn main() {
    let instances = suites::suite_lhg();
    let threads = [1usize, 2, 4];
    let presets = [Preset::Deterministic, Preset::Default, Preset::Quality];

    for preset in presets {
        // per thread count: total times and phase times (geo-mean)
        let mut totals: Vec<Vec<f64>> = vec![Vec::new(); threads.len()];
        let mut phase_times: Vec<Vec<Vec<f64>>> =
            vec![vec![Vec::new(); PHASES.len()]; threads.len()];
        for inst in &instances {
            for (ti, &t) in threads.iter().enumerate() {
                let mut ctx = Context::new(preset, 8, 0.03).with_threads(t).with_seed(3);
                ctx.contraction_limit_factor = 24;
                ctx.ip_min_repetitions = 2;
                ctx.ip_max_repetitions = 4;
                ctx.fm_max_rounds = 3;
                let start = Instant::now();
                let _ = partitioner::partition_arc(inst.hg.clone(), &ctx);
                totals[ti].push(start.elapsed().as_secs_f64());
                for (pi, phase) in PHASES.iter().enumerate() {
                    let secs = ctx.timer.get(phase).as_secs_f64();
                    if secs > 0.0 {
                        phase_times[ti][pi].push(secs);
                    }
                }
            }
        }
        let base = stats::geometric_mean(&totals[0]);
        let mut rows = vec![{
            let mut row = vec!["TOTAL".to_string(), format!("{base:.3}s")];
            for ti in 1..threads.len() {
                row.push(format!("{:.2}", base / stats::geometric_mean(&totals[ti]).max(1e-12)));
            }
            row
        }];
        for (pi, phase) in PHASES.iter().enumerate() {
            if phase_times[0][pi].is_empty() {
                continue;
            }
            let pbase = stats::geometric_mean(&phase_times[0][pi]);
            let mut row = vec![phase.to_string(), format!("{pbase:.3}s")];
            for ti in 1..threads.len() {
                let pt = stats::geometric_mean(&phase_times[ti][pi]);
                row.push(format!("{:.2}", pbase / pt.max(1e-12)));
            }
            rows.push(row);
        }
        benchkit::print_table(
            &format!("Table 1 / Figs. 12-13 — self-relative speedups, {}", preset.name()),
            &["phase", "t=1 time", "speedup t=2", "speedup t=4"],
            &rows,
        );
    }
    println!(
        "\n=> paper expectation (64 cores): SDet 28.8x, D 20.5x, Q 23.7x at t=64; \
         on this 1-vCPU container the measured values quantify threading overhead only."
    );
    

}
