"""L1 correctness: Pallas gain-tile kernel vs the pure-jnp oracle.

Hypothesis sweeps tile shapes, weights and assignments; the kernel must
match ref.py to float32 tolerance — this is the CORE correctness signal
for the AOT path.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gain_tiles as k
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def random_instance(rng, tn, tv, kk, density=0.2, max_w=4):
    a = (rng.random((tn, tv)) < density).astype(np.float32)
    w = rng.integers(1, max_w + 1, size=tn).astype(np.float32)
    blocks = rng.integers(0, kk, size=tv)
    x = np.zeros((tv, kk), dtype=np.float32)
    x[np.arange(tv), blocks] = 1.0
    return jnp.asarray(a), jnp.asarray(w), jnp.asarray(x)


@settings(max_examples=25, deadline=None)
@given(
    tn=st.sampled_from([8, 16, 64, 128]),
    tv=st.sampled_from([8, 32, 128]),
    kk=st.sampled_from([2, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_ref(tn, tv, kk, seed):
    rng = np.random.default_rng(seed)
    a, w, x = random_instance(rng, tn, tv, kk)
    phi_p, ben_p, pen_p = k.gain_tiles(a, w, x)
    phi_r, ben_r, pen_r = ref.gain_tiles_ref(a, w, x)
    np.testing.assert_allclose(phi_p, phi_r, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(ben_p, ben_r, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(pen_p, pen_r, rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 64, 256]),
    n=st.sampled_from([1, 16, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matmul_matches(m, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, m)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((m, n)).astype(np.float32))
    np.testing.assert_allclose(k.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_gain_semantics_tiny():
    """Hand-checkable case mirroring the Rust partition unit tests."""
    # 2 nets over 4 nodes, 2 blocks; net0={v0,v1} (block 0,0), net1={v1,v2,v3}
    a = jnp.asarray([[1, 1, 0, 0], [0, 1, 1, 1]], dtype=jnp.float32)
    w = jnp.asarray([3.0, 5.0])
    x = jnp.asarray([[1, 0], [1, 0], [0, 1], [0, 1]], dtype=jnp.float32)
    phi, ben, pen = k.gain_tiles(a, w, x)
    np.testing.assert_allclose(phi, [[2, 0], [1, 2]])
    # v1 is the lone block-0 pin of net1 -> benefit 5
    np.testing.assert_allclose(ben, [0, 5, 0, 0])
    # penalty of moving v0 to block 1: net0 has no block-1 pins -> 3
    np.testing.assert_allclose(pen[0], [0, 3])
    # gains match the paper's definition g = b - p
    gains = ref.gains_ref(a, w, x)
    np.testing.assert_allclose(gains[1, 1], 5 - 3)  # v1 -> block 1


def test_zero_density_edge_case():
    a = jnp.zeros((8, 8), dtype=jnp.float32)
    w = jnp.ones((8,), dtype=jnp.float32)
    x = jnp.eye(8, 4, dtype=jnp.float32)
    phi, ben, pen = k.gain_tiles(a, w, x)
    assert float(jnp.abs(phi).sum()) == 0.0
    assert float(jnp.abs(ben).sum()) == 0.0
    # every net has zero pins everywhere -> full penalty mass
    np.testing.assert_allclose(pen, ref.gain_tiles_ref(a, w, x)[2])


def test_weighted_nets_scale_linearly():
    rng = np.random.default_rng(7)
    a, w, x = random_instance(rng, 16, 16, 4)
    _, ben1, pen1 = k.gain_tiles(a, w, x)
    _, ben2, pen2 = k.gain_tiles(a, 2.0 * w, x)
    np.testing.assert_allclose(ben2, 2.0 * ben1, rtol=1e-6)
    np.testing.assert_allclose(pen2, 2.0 * pen1, rtol=1e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
