"""L2 correctness: spectral bipartitioner semantics + AOT emission."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def two_cliques_adj(n_half, n_total):
    adj = np.zeros((n_total, n_total), dtype=np.float32)
    for i in range(n_half):
        for j in range(n_half):
            if i != j:
                adj[i, j] = 1.0
                adj[n_half + i, n_half + j] = 1.0
    adj[0, n_half] = adj[n_half, 0] = 1.0  # bridge
    return adj


def test_spectral_separates_two_cliques():
    n = model.SPECTRAL_N
    adj = two_cliques_adj(20, n)
    deg = adj.sum(axis=1)
    fiedler = np.asarray(model.spectral_bipartition(jnp.asarray(adj), jnp.asarray(deg)))
    left = fiedler[:20]
    right = fiedler[20:40]
    # the two cliques take opposite signs
    assert (np.sign(left.mean()) != np.sign(right.mean())), (left.mean(), right.mean())
    # and each clique is internally sign-coherent
    assert (np.sign(left) == np.sign(left.mean())).mean() > 0.9
    assert (np.sign(right) == np.sign(right.mean())).mean() > 0.9


def test_spectral_padding_is_inert():
    n = model.SPECTRAL_N
    adj = two_cliques_adj(10, n)
    deg = adj.sum(axis=1)
    fiedler = np.asarray(model.spectral_bipartition(jnp.asarray(adj), jnp.asarray(deg)))
    assert np.isfinite(fiedler).all()


def test_gain_oracle_shapes():
    import jax

    a = jnp.zeros((128, 128), dtype=jnp.float32)
    w = jnp.ones((128,), dtype=jnp.float32)
    x = jnp.zeros((128, 16), dtype=jnp.float32).at[:, 0].set(1.0)
    phi, ben, pen = model.gain_oracle(a, w, x)
    assert phi.shape == (128, 16)
    assert ben.shape == (128,)
    assert pen.shape == (128, 16)
    del jax


def test_hlo_emission_contains_entry():
    txt = aot.lower_gain_oracle()
    assert "ENTRY" in txt and "f32[128,128]" in txt
    txt2 = aot.lower_spectral()
    assert "ENTRY" in txt2 and f"f32[{model.SPECTRAL_N}," in txt2
