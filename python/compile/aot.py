"""AOT lowering: jax → stablehlo → XlaComputation → **HLO text**.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written (build-time only; Python never runs on the request
path):
    artifacts/gain_tiles.hlo.txt   — L1 gain-tile kernel (TN×TV×K tile)
    artifacts/spectral.hlo.txt     — L2 spectral bipartitioner (N=256)
    artifacts/manifest.txt         — shapes, for the Rust loader
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import gain_tiles as k


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_gain_oracle() -> str:
    lowered = jax.jit(model.gain_oracle).lower(*model.gain_example_args())
    return to_hlo_text(lowered)


def lower_spectral() -> str:
    lowered = jax.jit(model.spectral_bipartition).lower(*model.spectral_example_args())
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    gain_txt = lower_gain_oracle()
    with open(os.path.join(args.out_dir, "gain_tiles.hlo.txt"), "w") as f:
        f.write(gain_txt)
    print(f"gain_tiles.hlo.txt: {len(gain_txt)} chars")

    spectral_txt = lower_spectral()
    with open(os.path.join(args.out_dir, "spectral.hlo.txt"), "w") as f:
        f.write(spectral_txt)
    print(f"spectral.hlo.txt: {len(spectral_txt)} chars")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write(
            "gain_tiles TN={} TV={} K={}\nspectral N={} ITERS={}\n".format(
                k.TN, k.TV, k.K, model.SPECTRAL_N, model.SPECTRAL_ITERS
            )
        )
    print("manifest.txt written")


if __name__ == "__main__":
    main()
