"""L2 — JAX compute graphs lowered to the AOT artifacts.

Two graphs, both calling the L1 Pallas kernels:

* ``gain_oracle``     — the batched gain-tile computation (Φ, b, p) used
  by the Rust coordinator's dense gain path.
* ``spectral_step`` / ``spectral_bipartition`` — power iteration for the
  Fiedler vector of the normalized adjacency, the extra portfolio member
  of initial partitioning (paper §5 uses nine flat techniques; this is
  the tenth, AOT-compiled one).
"""

import jax
import jax.numpy as jnp

from compile.kernels import gain_tiles as k

SPECTRAL_N = 256
SPECTRAL_ITERS = 60


def gain_oracle(a, w, x):
    """(Φ, benefit, penalty) for one incidence tile — L1 kernel pass-through."""
    return k.gain_tiles(a, w, x)


def spectral_bipartition(adj, deg):
    """Approximate Fiedler vector of the normalized Laplacian.

    adj: f32[N, N] dense (padded) adjacency; deg: f32[N] degrees
    (0 for padding). Returns f32[N] — sign gives the bipartition, the
    Rust side applies the balance-constrained threshold.

    B = D^{-1/2} A D^{-1/2}; its leading eigenvector is v1 ∝ √deg. Power
    iteration on B with v1 deflated converges to the second eigenvector,
    whose sign structure is the spectral bipartition.
    """
    d_isqrt = jnp.where(deg > 0.0, 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-12)), 0.0)
    v1 = jnp.sqrt(jnp.maximum(deg, 0.0))
    v1 = v1 / jnp.maximum(jnp.linalg.norm(v1), 1e-12)
    n = adj.shape[0]

    # deterministic pseudo-random start (fixed at trace time)
    x0 = jnp.cos(jnp.arange(n, dtype=jnp.float32) * 12.9898) * 0.5
    x0 = x0 - jnp.dot(x0, v1) * v1

    def step(_, x):
        # B·x via the Pallas matmul kernel: (D^{-1/2} A D^{-1/2}) x
        y = k.matmul(adj, (x * d_isqrt)[:, None])[:, 0] * d_isqrt
        # shift to make the spectrum positive (power iteration stability)
        y = y + x
        y = y - jnp.dot(y, v1) * v1
        return y / jnp.maximum(jnp.linalg.norm(y), 1e-12)

    x = jax.lax.fori_loop(0, SPECTRAL_ITERS, step, x0)
    return x


def spectral_example_args():
    spec = jax.ShapeDtypeStruct((SPECTRAL_N, SPECTRAL_N), jnp.float32)
    dspec = jax.ShapeDtypeStruct((SPECTRAL_N,), jnp.float32)
    return (spec, dspec)


def gain_example_args():
    return (
        jax.ShapeDtypeStruct((k.TN, k.TV), jnp.float32),
        jax.ShapeDtypeStruct((k.TN,), jnp.float32),
        jax.ShapeDtypeStruct((k.TV, k.K), jnp.float32),
    )
