"""L1 — the dense gain-tile Pallas kernel.

The paper's gain computation (§6) is a scalar gather/scatter over sparse
incidence structure. The TPU-shaped re-think (DESIGN.md §Hardware-
Adaptation): the Rust coordinator packs boundary regions into dense
incidence tiles ``A ∈ {0,1}^{TN×TV}`` and a one-hot block-assignment tile
``X ∈ {0,1}^{TV×K}``; pin counts, benefit and penalty terms then become
three MXU matmuls plus elementwise selects:

    Φ       = A · X                                  (pin counts)
    penalty = Aᵀ · (w ⊙ 1[Φ = 0])                    (p(v, t) terms)
    benefit = Σ_t X[v,t] · (Aᵀ · (w ⊙ 1[Φ = 1]))[v,t]  (b(v) terms)

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is *estimated* in DESIGN.md §7.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# AOT tile shape (multiples of (8, 128) for f32 MXU tiles)
TN = 128  # nets per tile
TV = 128  # nodes per tile
K = 16    # blocks per tile


def _gain_tile_kernel(a_ref, w_ref, x_ref, phi_ref, benefit_ref, penalty_ref):
    """Pallas kernel body: one (TN × TV) incidence tile."""
    a = a_ref[...]          # [TN, TV]
    w = w_ref[...]          # [TN]
    x = x_ref[...]          # [TV, K]
    phi = a @ x             # [TN, K]  — MXU matmul 1
    phi_ref[...] = phi
    wc = w[:, None]
    # penalty: nets with zero pins in t penalize moving v into t
    pen_mask = jnp.where(phi == 0.0, wc, 0.0)        # [TN, K]
    penalty = a.T @ pen_mask                          # MXU matmul 2
    penalty_ref[...] = penalty
    # benefit: nets where v is the last pin of its own block
    ben_mask = jnp.where(phi == 1.0, wc, 0.0)        # [TN, K]
    ben_full = a.T @ ben_mask                         # MXU matmul 3
    benefit_ref[...] = jnp.sum(ben_full * x, axis=1)  # select own block


@functools.partial(jax.jit, static_argnames=())
def gain_tiles(a, w, x):
    """Compute (Φ, benefit, penalty) for one dense incidence tile.

    a: f32[TN, TV] 0/1 incidence; w: f32[TN] net weights;
    x: f32[TV, K] one-hot block assignment.
    Returns (phi[TN, K], benefit[TV], penalty[TV, K]).
    """
    tn, tv = a.shape
    k = x.shape[1]
    return pl.pallas_call(
        _gain_tile_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((tn, k), jnp.float32),
            jax.ShapeDtypeStruct((tv,), jnp.float32),
            jax.ShapeDtypeStruct((tv, k), jnp.float32),
        ),
        interpret=True,
    )(a, w, x)


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] @ b_ref[...]


def matmul(a, b):
    """Single-tile Pallas matmul (used by the L2 spectral model)."""
    m, _ = a.shape
    _, n = b.shape
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
