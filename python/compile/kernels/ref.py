"""Pure-jnp reference oracle for the Pallas kernels (correctness signal).

Mirrors the definitions of paper §6 directly:
    b(v)     = Σ_{e ∈ I(v)} ω(e) · 1[Φ(e, Π[v]) = 1]
    p(v, t)  = Σ_{e ∈ I(v)} ω(e) · 1[Φ(e, t) = 0]
"""

import jax.numpy as jnp


def gain_tiles_ref(a, w, x):
    """Reference (Φ, benefit, penalty) — no Pallas, plain jnp."""
    phi = a @ x
    wc = w[:, None]
    penalty = a.T @ jnp.where(phi == 0.0, wc, 0.0)
    ben_full = a.T @ jnp.where(phi == 1.0, wc, 0.0)
    benefit = jnp.sum(ben_full * x, axis=1)
    return phi, benefit, penalty


def matmul_ref(a, b):
    return a @ b


def gains_ref(a, w, x):
    """Full move-gain matrix g[v, t] = benefit[v] − penalty[v, t]."""
    _, benefit, penalty = gain_tiles_ref(a, w, x)
    return benefit[:, None] - penalty
